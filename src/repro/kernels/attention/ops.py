"""Batched/multi-head wrapper + tunable declaration for flash attention."""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ...core import SearchSpace, Tuner, TuningCache
from ...core.profiles import DeviceProfile, TPU_V5E
from ...core.registry import AutotunePolicy, Shape, lookup, tunable
from ...core.space import Config
from .flash import (analytical_time, make_flash_attention,
                    vmem_footprint)
from .ref import attention_reference

KERNEL_NAME = "flash_attention"


def _shape(Sq: int, Sk: int, D: int, causal: bool = True) -> Dict[str, Any]:
    return {"Sq": Sq, "Sk": Sk, "D": D, "causal": bool(causal)}


def shape_key(Sq: int, Sk: int, D: int, causal: bool = True) -> str:
    return f"Sq{Sq}_Sk{Sk}_D{D}_{'c' if causal else 'f'}"


def heuristic_config(Sq: int, Sk: int) -> Dict[str, Any]:
    def pick(d, cands):
        for c in cands:
            if d % c == 0:
                return c
        # no candidate divides d: return d itself — likely out of the
        # declared value list, which the registry's feasibility projection
        # (project_feasible) repairs to the nearest in-space point
        return d
    # PIPELINE_DEPTH is declared explicitly: a heuristic must cover every
    # space parameter or the constraint check reads it as a violation
    return {"BLOCK_Q": pick(Sq, (512, 256, 128, 64)),
            "BLOCK_K": pick(Sk, (1024, 512, 256, 128, 64)),
            "PIPELINE_DEPTH": 2}


def tuning_space():
    params = {
        "BLOCK_Q": (64, 128, 256, 512, 1024),
        "BLOCK_K": (64, 128, 256, 512, 1024, 2048),
        "PIPELINE_DEPTH": (2, 3),
    }
    return params, []


def _space(shape: Shape) -> SearchSpace:
    Sq, Sk = shape["Sq"], shape["Sk"]
    params, constraints = tuning_space()
    sp = SearchSpace()
    for name, values in params.items():
        sp.add_parameter(name=name, values=values)
    for fn, names, label in constraints:
        sp.add_constraint(fn, names, label)
    sp.add_constraint(lambda bq: Sq % bq == 0, ("BLOCK_Q",), "Sq % BLOCK_Q")
    sp.add_constraint(lambda bk: Sk % bk == 0, ("BLOCK_K",), "Sk % BLOCK_K")
    return sp


def _make_args(shape: Shape, rng: np.random.Generator):
    Sq, Sk, D = shape["Sq"], shape["Sk"], shape["D"]
    mk = lambda s: jnp.asarray(rng.normal(size=s) * 0.5, jnp.float32)
    return mk((Sq, D)), mk((Sk, D)), mk((Sk, D))


def _arg_specs(shape: Shape):
    Sq, Sk, D = shape["Sq"], shape["Sk"], shape["D"]
    f32 = jnp.float32
    return (jax.ShapeDtypeStruct((Sq, D), f32),
            jax.ShapeDtypeStruct((Sk, D), f32),
            jax.ShapeDtypeStruct((Sk, D), f32))


def _elt_bytes(shape: Shape) -> int:
    """Activation element width from the shape's dtype (default float32)."""
    return jnp.dtype(shape.get("dtype", "float32")).itemsize


@tunable(
    name=KERNEL_NAME,
    space=_space,
    heuristic=lambda s: heuristic_config(s["Sq"], s["Sk"]),
    shape_key=lambda s: shape_key(s["Sq"], s["Sk"], s["D"],
                                  s.get("causal", True)),
    make_args=_make_args,
    arg_specs=_arg_specs,
    # dtype threads through model and footprint with the same element
    # width so static VMEM proofs agree with the analytical cliff
    analytical_model=lambda s, cfg, prof: analytical_time(
        cfg, prof, s["Sq"], s["Sk"], s["D"],
        causal=s.get("causal", True), elt_bytes=_elt_bytes(s)),
    vmem_footprint=lambda s, cfg: vmem_footprint(
        cfg, s["D"], elt_bytes=_elt_bytes(s)),
    reference=lambda s: (lambda q, k, v: attention_reference(
        q, k, v, causal=s.get("causal", True))),
    default_shapes=(_shape(4096, 4096, 128, causal=True),),
    defaults={"strategy": "annealing", "budget": 40},
    tags=("beyond-paper", "attention"))
def FLASH_ATTENTION(shape: Shape, config: Config, *, interpret: bool = False):
    """Flash attention (beyond paper; same tuning methodology)."""
    return make_flash_attention(shape["Sq"], shape["Sk"], shape["D"], config,
                                causal=shape.get("causal", True),
                                interpret=interpret)


def lookup_config(Sq: int, Sk: int, D: int, causal: bool = True,
                  profile: DeviceProfile = TPU_V5E,
                  cache: Optional[TuningCache] = None,
                  policy: "AutotunePolicy | str | None" = None
                  ) -> Dict[str, Any]:
    return lookup(FLASH_ATTENTION, _shape(Sq, Sk, D, causal),
                  profile=profile, cache=cache, policy=policy)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True,
                    config: Optional[Dict[str, Any]] = None,
                    profile: DeviceProfile = TPU_V5E,
                    interpret: bool = False,
                    policy: "AutotunePolicy | str | None" = None):
    """q: (..., Sq, D), k/v: (..., Sk, D); leading dims vmapped."""
    *lead, Sq, D = q.shape
    Sk = k.shape[-2]
    cfg = config or lookup_config(Sq, Sk, D, causal, profile, policy=policy)
    fn = make_flash_attention(Sq, Sk, D, cfg, causal=causal,
                              dtype=q.dtype, interpret=interpret)
    for _ in lead:
        fn = jax.vmap(fn)
    return fn(q, k, v)


# ---------------------------------------------------------------------------
# legacy tuner integration — thin delegates to the generic API
# ---------------------------------------------------------------------------

def make_tuner(Sq: int, Sk: int, D: int, *, causal: bool = True,
               evaluator=None, profile: DeviceProfile = TPU_V5E,
               interpret: bool = True) -> Tuner:
    return Tuner.from_tunable(FLASH_ATTENTION, _shape(Sq, Sk, D, causal),
                              evaluator=evaluator, profile=profile,
                              interpret=interpret)


def tune_flash_attention(Sq: int, Sk: int, D: int, *, causal: bool = True,
                         strategy: str = "annealing", budget: int = 40,
                         profile: DeviceProfile = TPU_V5E,
                         record: bool = True, seed: int = 0, **kwargs):
    from ...tune.api import tune_kernel
    return tune_kernel(FLASH_ATTENTION, _shape(Sq, Sk, D, causal),
                       strategy=strategy, budget=budget, profile=profile,
                       record=record, seed=seed, **kwargs)
