"""Batched/multi-head wrapper + tuner integration for flash attention."""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ...core import TPUAnalyticalEvaluator, Tuner, default_cache
from ...core.profiles import DeviceProfile, TPU_V5E
from ...core.space import Config
from .flash import (DEFAULT_CONFIG, analytical_time, make_flash_attention,
                    vmem_footprint)
from .ref import attention_reference

KERNEL_NAME = "flash_attention"


def shape_key(Sq: int, Sk: int, D: int, causal: bool = True) -> str:
    return f"Sq{Sq}_Sk{Sk}_D{D}_{'c' if causal else 'f'}"


def heuristic_config(Sq: int, Sk: int) -> Dict[str, Any]:
    def pick(d, cands):
        for c in cands:
            if d % c == 0:
                return c
        return d
    return {"BLOCK_Q": pick(Sq, (512, 256, 128, 64)),
            "BLOCK_K": pick(Sk, (1024, 512, 256, 128, 64))}


def lookup_config(Sq: int, Sk: int, D: int, causal: bool = True,
                  profile: DeviceProfile = TPU_V5E) -> Dict[str, Any]:
    entry = default_cache().get(KERNEL_NAME, shape_key(Sq, Sk, D, causal),
                                profile.name)
    return dict(entry.config) if entry else heuristic_config(Sq, Sk)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True,
                    config: Optional[Dict[str, Any]] = None,
                    profile: DeviceProfile = TPU_V5E,
                    interpret: bool = False):
    """q: (..., Sq, D), k/v: (..., Sk, D); leading dims vmapped."""
    *lead, Sq, D = q.shape
    Sk = k.shape[-2]
    cfg = config or lookup_config(Sq, Sk, D, causal, profile)
    fn = make_flash_attention(Sq, Sk, D, cfg, causal=causal,
                              dtype=q.dtype, interpret=interpret)
    for _ in lead:
        fn = jax.vmap(fn)
    return fn(q, k, v)


def tuning_space():
    params = {
        "BLOCK_Q": (64, 128, 256, 512, 1024),
        "BLOCK_K": (64, 128, 256, 512, 1024, 2048),
        "PIPELINE_DEPTH": (2, 3),
    }
    return params, []


def make_tuner(Sq: int, Sk: int, D: int, *, causal: bool = True,
               evaluator=None, profile: DeviceProfile = TPU_V5E,
               interpret: bool = True) -> Tuner:
    evaluator = evaluator or TPUAnalyticalEvaluator(profile=profile)

    def build(cfg: Config):
        return make_flash_attention(Sq, Sk, D, cfg, causal=causal,
                                    interpret=interpret)

    def make_args(rng: np.random.Generator):
        mk = lambda s: jnp.asarray(rng.normal(size=s) * 0.5, jnp.float32)
        return mk((Sq, D)), mk((Sk, D)), mk((Sk, D))

    def arg_specs():
        f32 = jnp.float32
        return (jax.ShapeDtypeStruct((Sq, D), f32),
                jax.ShapeDtypeStruct((Sk, D), f32),
                jax.ShapeDtypeStruct((Sk, D), f32))

    tuner = Tuner(evaluator=evaluator, profile=profile)
    tuner.set_reference(
        lambda q, k, v: attention_reference(q, k, v, causal=causal))
    tuner.add_kernel(
        build, name=KERNEL_NAME, make_args=make_args, arg_specs=arg_specs,
        analytical_model=lambda cfg, prof: analytical_time(
            cfg, prof, Sq, Sk, D, causal=causal),
        vmem_footprint=lambda cfg: vmem_footprint(cfg, D),
        meta={"Sq": Sq, "Sk": Sk, "D": D})
    params, constraints = tuning_space()
    for name, values in params.items():
        tuner.add_parameter(name, values)
    tuner.add_constraint(lambda bq: Sq % bq == 0, ("BLOCK_Q",), "Sq % BLOCK_Q")
    tuner.add_constraint(lambda bk: Sk % bk == 0, ("BLOCK_K",), "Sk % BLOCK_K")
    return tuner


def tune_flash_attention(Sq: int, Sk: int, D: int, *, causal: bool = True,
                         strategy: str = "annealing", budget: int = 40,
                         profile: DeviceProfile = TPU_V5E,
                         record: bool = True, seed: int = 0, **kwargs):
    tuner = make_tuner(Sq, Sk, D, causal=causal, profile=profile, **kwargs)
    return tuner.tune(strategy=strategy, budget=budget, seed=seed,
                      record_to_cache=record,
                      shape_key=shape_key(Sq, Sk, D, causal))
