"""Pure-jnp oracle for the GEMM case study.

Paper form (section VI): C = alpha * A^T B + beta * C, single precision,
power-of-two dims.  ``trans_a`` selects whether A arrives K-major (the
paper's A^T layout) or M-major.
"""

from __future__ import annotations

import jax.numpy as jnp


def gemm_reference(a, b, c=None, *, alpha: float = 1.0, beta: float = 0.0,
                   trans_a: bool = False, acc_dtype=jnp.float32):
    """C = alpha * op(A) @ B + beta * C with op(A) = A^T if trans_a.

    a: (M, K) or (K, M) when trans_a; b: (K, N); returns (M, N) in a.dtype.
    """
    lhs = a.T if trans_a else a
    out = jnp.dot(lhs.astype(acc_dtype), b.astype(acc_dtype),
                  preferred_element_type=acc_dtype)
    out = alpha * out
    if c is not None and beta != 0.0:
        out = out + beta * c.astype(acc_dtype)
    return out.astype(a.dtype)
