"""Tunable Pallas GEMM — the paper's matrix-multiplication case study on TPU.

Parameter vocabulary (TPU re-derivation of paper Table IV; see DESIGN.md §2):

  BLOCK_M / BLOCK_N / BLOCK_K   VMEM tile sizes       (paper: M_wg/N_wg/K_wg)
  GRID_ORDER  'mn' | 'nm'       outer-loop traversal  (paper: implicit in
                                workgroup scheduling)
  INNER_STEPS 1|2|4|8           K sub-step unroll     (paper: K_wi unroll)
  ACC_DTYPE   float32|bfloat16  accumulator precision (paper: no analogue —
                                MXU-specific; bf16 accumulation trades
                                accuracy for VMEM, verification catches it
                                when it breaks)
  ACC_IN_OUTPUT True|False      accumulate into the output block instead of a
                                scratch buffer (saves one BMxBN VMEM buffer;
                                requires ACC_DTYPE == out dtype)
  TRANS_A     True|False        A arrives K-major (paper computes A^T B)

Analytic-model-only parameters (affect the TPUAnalyticalEvaluator, not the
kernel build — they model compiler/pipeline choices Pallas fixes for us):
PIPELINE_DEPTH, NBUF_OUT, PACK.  The benchmark space that reproduces the
paper's ">200k configurations" claim includes them; build() ignores them.
"""

from __future__ import annotations

import functools
import math
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ...core.profiles import DeviceProfile

Config = Dict[str, Any]

DEFAULT_CONFIG: Config = {
    "BLOCK_M": 512, "BLOCK_N": 512, "BLOCK_K": 512,
    "GRID_ORDER": "mn", "INNER_STEPS": 1,
    "ACC_DTYPE": "float32", "ACC_IN_OUTPUT": False, "TRANS_A": False,
}


def _dtype(name: str):
    return jnp.dtype(name)


# ---------------------------------------------------------------------------
# kernel bodies
# ---------------------------------------------------------------------------

def _mm_kernel_scratch(a_ref, b_ref, o_ref, acc_ref, *, nk: int,
                       inner_steps: int, acc_dtype, trans_a: bool):
    """K-accumulation into a VMEM scratch accumulator."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]
    if trans_a:
        a = a.T                     # block arrives (BK, BM): transpose in VREGs
    b = b_ref[...]
    if inner_steps == 1:
        acc_ref[...] += jnp.dot(a, b, preferred_element_type=acc_dtype)
    else:
        # K_wi unroll: split the BK dimension into inner_steps sub-dots.
        # On TPU this shortens MXU dependency chains for small blocks.
        step = a.shape[1] // inner_steps
        acc = acc_ref[...]
        for s in range(inner_steps):
            acc += jnp.dot(a[:, s * step:(s + 1) * step],
                           b[s * step:(s + 1) * step, :],
                           preferred_element_type=acc_dtype)
        acc_ref[...] = acc

    @pl.when(pl.program_id(2) == nk - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _mm_kernel_inplace(a_ref, b_ref, o_ref, *, nk: int, inner_steps: int,
                       acc_dtype, trans_a: bool):
    """K-accumulation directly into the output block (ACC_IN_OUTPUT)."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...]
    if trans_a:
        a = a.T
    b = b_ref[...]
    if inner_steps == 1:
        o_ref[...] += jnp.dot(a, b, preferred_element_type=acc_dtype)
    else:
        step = a.shape[1] // inner_steps
        acc = o_ref[...]
        for s in range(inner_steps):
            acc += jnp.dot(a[:, s * step:(s + 1) * step],
                           b[s * step:(s + 1) * step, :],
                           preferred_element_type=acc_dtype)
        o_ref[...] = acc


# ---------------------------------------------------------------------------
# pallas_call builder
# ---------------------------------------------------------------------------

def validate_config(config: Config, M: int, N: int, K: int) -> None:
    bm, bn, bk = config["BLOCK_M"], config["BLOCK_N"], config["BLOCK_K"]
    if M % bm or N % bn or K % bk:
        raise ValueError(f"dims ({M},{N},{K}) not divisible by blocks "
                         f"({bm},{bn},{bk})")
    if bk % config["INNER_STEPS"]:
        raise ValueError("BLOCK_K must divide by INNER_STEPS")
    if config["ACC_IN_OUTPUT"] and config["ACC_DTYPE"] != "float32":
        raise ValueError("ACC_IN_OUTPUT requires float32 accumulation")


def make_matmul(M: int, N: int, K: int, config: Config | None = None,
                out_dtype=jnp.float32, interpret: bool = False):
    """Return fn(a, b) -> a @ b with the given tile configuration.

    ``a`` is (M, K), or (K, M) when TRANS_A (paper's A^T input layout).
    """
    cfg = dict(DEFAULT_CONFIG)
    cfg.update(config or {})
    validate_config(cfg, M, N, K)
    bm, bn, bk = cfg["BLOCK_M"], cfg["BLOCK_N"], cfg["BLOCK_K"]
    trans_a = bool(cfg["TRANS_A"])
    acc_dtype = _dtype(cfg["ACC_DTYPE"])
    nk = K // bk
    gm, gn = M // bm, N // bn

    # grid traversal order: 'mn' = M outer; 'nm' = N outer.  K is always the
    # innermost ("arbitrary") dimension so accumulation steps are consecutive.
    if cfg["GRID_ORDER"] == "mn":
        grid = (gm, gn, nk)
        a_idx = (lambda m, n, k: (k, m)) if trans_a else (lambda m, n, k: (m, k))
        b_idx = lambda m, n, k: (k, n)
        o_idx = lambda m, n, k: (m, n)
    elif cfg["GRID_ORDER"] == "nm":
        grid = (gn, gm, nk)
        a_idx = (lambda n, m, k: (k, m)) if trans_a else (lambda n, m, k: (m, k))
        b_idx = lambda n, m, k: (k, n)
        o_idx = lambda n, m, k: (m, n)
    else:
        raise ValueError(f"bad GRID_ORDER {cfg['GRID_ORDER']!r}")

    a_block = (bk, bm) if trans_a else (bm, bk)
    in_specs = [pl.BlockSpec(a_block, a_idx),
                pl.BlockSpec((bk, bn), b_idx)]
    out_spec = pl.BlockSpec((bm, bn), o_idx)
    out_shape = jax.ShapeDtypeStruct((M, N), out_dtype)

    common = dict(nk=nk, inner_steps=cfg["INNER_STEPS"],
                  acc_dtype=acc_dtype, trans_a=trans_a)
    kwargs: Dict[str, Any] = dict(
        grid=grid, in_specs=in_specs, out_specs=out_spec,
        out_shape=out_shape, interpret=interpret)
    if not interpret:
        # M/N grid dims are embarrassingly parallel; K carries the
        # accumulator dependency.
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))

    if cfg["ACC_IN_OUTPUT"]:
        kernel = functools.partial(_mm_kernel_inplace, **common)
    else:
        kernel = functools.partial(_mm_kernel_scratch, **common)
        kwargs["scratch_shapes"] = [pltpu.VMEM((bm, bn), acc_dtype)]

    return pl.pallas_call(kernel, **kwargs)


# ---------------------------------------------------------------------------
# structural cost models (feed TPUAnalyticalEvaluator and auto-constraints)
# ---------------------------------------------------------------------------

def vmem_footprint(config: Config, elt_bytes: int = 4,
                   out_bytes: int = 4) -> int:
    """Bytes of VMEM the configuration claims (double-buffered inputs)."""
    cfg = dict(DEFAULT_CONFIG)
    cfg.update(config)
    bm, bn, bk = cfg["BLOCK_M"], cfg["BLOCK_N"], cfg["BLOCK_K"]
    nbuf_in = int(cfg.get("PIPELINE_DEPTH", 2))
    nbuf_out = int(cfg.get("NBUF_OUT", 1))
    acc_bytes = jnp.dtype(cfg["ACC_DTYPE"]).itemsize
    buf = nbuf_in * (bm * bk + bk * bn) * elt_bytes
    out = nbuf_out * bm * bn * out_bytes
    acc = 0 if cfg["ACC_IN_OUTPUT"] else bm * bn * acc_bytes
    return buf + out + acc


def analytical_time(config: Config, profile: DeviceProfile,
                    M: int, N: int, K: int, elt_bytes: int = 4) -> float:
    """Structural pipeline model: max(MXU time, HBM time) per grid step.

    Captures the paper's search-space shape on TPU: VMEM cliff (infeasible),
    MXU misalignment penalties, HBM refetch growth as blocks shrink, pipeline
    ramp overheads for deep grids, and bf16-accumulation speedup.
    """
    cfg = dict(DEFAULT_CONFIG)
    cfg.update(config)
    bm, bn, bk = cfg["BLOCK_M"], cfg["BLOCK_N"], cfg["BLOCK_K"]
    if M % bm or N % bn or K % bk or bk % cfg["INNER_STEPS"]:
        return math.inf
    if cfg["ACC_IN_OUTPUT"] and cfg["ACC_DTYPE"] != "float32":
        return math.inf
    if vmem_footprint(cfg, elt_bytes) > profile.vmem_bytes:
        return math.inf                       # the paper's local-memory cliff

    mxu = profile.mxu_dim
    # MXU utilisation: padding waste for non-multiples of the systolic tile
    def _eff(d: int) -> float:
        return d / (math.ceil(d / mxu) * mxu)
    util = _eff(bm) * _eff(bn) * _eff(min(bk, mxu * 4))
    # TPU MXUs always accumulate in f32; a bf16 accumulator only saves VMEM
    # (already charged in the footprint) plus a small epilogue-cast saving.
    acc_speed = 1.0 if cfg["ACC_DTYPE"] == "float32" else 1.02
    # very deep inner unroll wastes VREGs; mild penalty beyond 4
    unroll_pen = 1.0 + 0.03 * max(0, cfg["INNER_STEPS"] - 4)
    # PACK models sublane packing of the minor dim (1 = none)
    pack_gain = {1: 1.0, 2: 1.06, 4: 1.09}.get(int(cfg.get("PACK", 1)), 1.0)

    flops = 2.0 * M * N * K
    # effective rate never exceeds the physical roofline
    rate = profile.peak_flops * min(
        1.0, util * acc_speed * pack_gain / unroll_pen)
    compute_t = flops / rate

    gm, gn, nk = M // bm, N // bn, K // bk
    steps = gm * gn * nk
    # HBM traffic: every (m,n,k) step streams one A and one B block; the
    # output block is written once per (m,n).  TRANS_A loads are contiguous
    # K-major (slightly cheaper on TPU, matching the paper's preference).
    a_bytes = steps * bm * bk * elt_bytes * (0.96 if cfg["TRANS_A"] else 1.0)
    b_bytes = steps * bk * bn * elt_bytes
    o_bytes = gm * gn * bm * bn * elt_bytes
    memory_t = (a_bytes + b_bytes + o_bytes) / profile.hbm_bw

    depth = int(cfg.get("PIPELINE_DEPTH", 2))
    # pipeline: deeper buffering hides more copy latency (memory side only —
    # the MXU floor is physical); costs VMEM (charged in the footprint).
    overlap = {2: 1.0, 3: 0.97, 4: 0.955}.get(depth, 1.0)
    bubble_t = steps * profile.grid_step_overhead / depth
    t = max(compute_t, memory_t * overlap) + bubble_t \
        + profile.launch_overhead
    return t


def flops(M: int, N: int, K: int) -> float:
    return 2.0 * M * N * K
