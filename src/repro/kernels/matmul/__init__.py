from .matmul import (DEFAULT_CONFIG, analytical_time, make_matmul,
                     validate_config, vmem_footprint)
from .ops import (GEMM, heuristic_config, lookup_config, make_tuner, matmul,
                  shape_key, tune_matmul, tuning_space)
from .ref import gemm_reference

__all__ = [
    "DEFAULT_CONFIG", "GEMM", "analytical_time", "make_matmul",
    "validate_config", "vmem_footprint", "heuristic_config", "lookup_config",
    "make_tuner", "matmul", "shape_key", "tune_matmul", "tuning_space",
    "gemm_reference",
]
