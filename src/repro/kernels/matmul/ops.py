"""Public entry point for the tuned GEMM, declared via the tunable registry.

``GEMM`` is the complete tuning declaration (space, heuristic, models,
reference) for the shape family; ``matmul(a, b)`` resolves its block
configuration through ``repro.core.registry.lookup`` — tuned-cache hit,
then heuristic, with optional tune-on-miss (CLTune scenario 3).  The old
per-kernel helpers (``make_tuner``/``tune_matmul``/``lookup_config``)
survive as thin delegates to the generic API.
"""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ...core import SearchSpace, Tuner, TuningCache
from ...core.profiles import DeviceProfile, TPU_V5E
from ...core.registry import AutotunePolicy, Shape, lookup, tunable
from ...core.space import Config
from . import ref
from .matmul import (analytical_time, make_matmul,
                     vmem_footprint)

KERNEL_NAME = "gemm"


def _shape(M: int, N: int, K: int, dtype="float32") -> Dict[str, Any]:
    return {"M": M, "N": N, "K": K, "dtype": jnp.dtype(dtype).name}


def shape_key(M: int, N: int, K: int, dtype="float32") -> str:
    return f"M{M}_N{N}_K{K}_{jnp.dtype(dtype).name}"


def heuristic_config(M: int, N: int, K: int) -> Dict[str, Any]:
    """Largest aligned blocks that divide the problem; sensible defaults."""
    def pick(d, cands):
        for c in cands:
            if d % c == 0:
                return c
        # nothing divides d (odd/prime dims): return d itself — the
        # registry's project_feasible repairs out-of-list values to the
        # nearest in-space point before the config is ever served
        return d
    return {
        "BLOCK_M": pick(M, (512, 256, 128, 64, 32, 16, 8)),
        "BLOCK_N": pick(N, (512, 256, 128, 64, 32, 16, 8)),
        "BLOCK_K": pick(K, (512, 256, 128, 64, 32, 16, 8)),
        "GRID_ORDER": "mn", "INNER_STEPS": 1,
        "ACC_DTYPE": "float32", "ACC_IN_OUTPUT": False, "TRANS_A": False,
    }


def tuning_space(extended: bool = False):
    """(values, constraints) for the GEMM space.

    ``extended=True`` is the paper-scale space (>200k configurations,
    benchmark Fig. 7); the compact space is what tests sweep with real
    Pallas-interpret execution.
    """
    if extended:
        params = {
            "BLOCK_M": (32, 64, 128, 256, 512, 1024),
            "BLOCK_N": (32, 64, 128, 256, 512, 1024),
            "BLOCK_K": (32, 64, 128, 256, 512, 1024),
            "GRID_ORDER": ("mn", "nm"),
            "INNER_STEPS": (1, 2, 4, 8),
            "ACC_DTYPE": ("float32", "bfloat16"),
            "ACC_IN_OUTPUT": (False, True),
            "TRANS_A": (False, True),
            "PIPELINE_DEPTH": (2, 3, 4),
            "NBUF_OUT": (1, 2),
            "PACK": (1, 2, 4),
        }
    else:
        params = {
            "BLOCK_M": (128, 256, 512),
            "BLOCK_N": (128, 256, 512),
            "BLOCK_K": (128, 256, 512),
            "GRID_ORDER": ("mn", "nm"),
            "INNER_STEPS": (1, 2),
            "ACC_DTYPE": ("float32",),
            "ACC_IN_OUTPUT": (False, True),
            "TRANS_A": (False,),
        }
    constraints = [
        (lambda bk, s: bk % s == 0, ("BLOCK_K", "INNER_STEPS"),
         "BLOCK_K divisible by INNER_STEPS"),
        (lambda acc_out, acc: (not acc_out) or acc == "float32",
         ("ACC_IN_OUTPUT", "ACC_DTYPE"), "in-place acc requires f32"),
    ]
    return params, constraints


def _space(shape: Shape, extended: bool = False) -> SearchSpace:
    M, N, K = shape["M"], shape["N"], shape["K"]
    params, constraints = tuning_space(extended=extended)
    sp = SearchSpace()
    for name, values in params.items():
        sp.add_parameter(name=name, values=values)
    for fn, names, label in constraints:
        sp.add_constraint(fn, names, label)
    # problem-size divisibility (device-independent feasibility)
    sp.add_constraint(lambda bm: M % bm == 0, ("BLOCK_M",), "M % BLOCK_M")
    sp.add_constraint(lambda bn: N % bn == 0, ("BLOCK_N",), "N % BLOCK_N")
    sp.add_constraint(lambda bk: K % bk == 0, ("BLOCK_K",), "K % BLOCK_K")
    return sp


def _make_args(shape: Shape, rng: np.random.Generator):
    M, N, K = shape["M"], shape["N"], shape["K"]
    a = jnp.asarray(rng.normal(size=(M, K)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(K, N)), jnp.float32)
    return a, b


def _arg_specs(shape: Shape):
    M, N, K = shape["M"], shape["N"], shape["K"]
    return (jax.ShapeDtypeStruct((M, K), jnp.float32),
            jax.ShapeDtypeStruct((K, N), jnp.float32))


def _elt_bytes(shape: Shape) -> int:
    """Input element width from the shape's dtype (default float32)."""
    return jnp.dtype(shape.get("dtype", "float32")).itemsize


@tunable(
    name=KERNEL_NAME,
    space=_space,
    heuristic=lambda s: heuristic_config(s["M"], s["N"], s["K"]),
    shape_key=lambda s: shape_key(s["M"], s["N"], s["K"],
                                  s.get("dtype", "float32")),
    make_args=_make_args,
    arg_specs=_arg_specs,
    # dtype threads through the model AND the footprint with the same
    # element width, so a static VMEM proof (repro.analyze) can never
    # disagree with the analytical cliff — pruning stays winner-identical
    analytical_model=lambda s, cfg, prof: analytical_time(
        cfg, prof, s["M"], s["N"], s["K"], elt_bytes=_elt_bytes(s)),
    vmem_footprint=lambda s, cfg: vmem_footprint(
        cfg, elt_bytes=_elt_bytes(s)),
    reference=lambda s: (lambda a, b: ref.gemm_reference(a, b)),
    default_shapes=(_shape(2048, 2048, 2048),),
    defaults={"strategy": "annealing", "budget": 100},
    tags=("paper-case-study", "gemm"))
def GEMM(shape: Shape, config: Config, *, interpret: bool = False):
    """The paper's section VI case study: Pallas-tiled GEMM."""
    return make_matmul(shape["M"], shape["N"], shape["K"], config,
                       interpret=interpret)


def lookup_config(M: int, N: int, K: int,
                  profile: DeviceProfile = TPU_V5E,
                  cache: Optional[TuningCache] = None,
                  policy: "AutotunePolicy | str | None" = None
                  ) -> Dict[str, Any]:
    return lookup(GEMM, _shape(M, N, K), profile=profile, cache=cache,
                  policy=policy)


def matmul(a: jax.Array, b: jax.Array, config: Optional[Dict[str, Any]] = None,
           *, alpha: float = 1.0, beta: float = 0.0,
           c: Optional[jax.Array] = None,
           profile: DeviceProfile = TPU_V5E, interpret: bool = False,
           policy: "AutotunePolicy | str | None" = None):
    """C = alpha * op(A) @ B (+ beta * C), Pallas-tiled.

    The alpha/beta epilogue runs in XLA (it fuses); the Pallas kernel does
    the FLOP-heavy product, as in the paper's GEMM.
    """
    trans = bool((config or {}).get("TRANS_A", False))
    M = a.shape[1] if trans else a.shape[0]
    K = a.shape[0] if trans else a.shape[1]
    N = b.shape[1]
    cfg = config or lookup_config(M, N, K, profile, policy=policy)
    fn = make_matmul(M, N, K, cfg, out_dtype=a.dtype, interpret=interpret)
    out = fn(a, b)
    if alpha != 1.0:
        out = alpha * out
    if c is not None and beta != 0.0:
        out = out + beta * c
    return out


# ---------------------------------------------------------------------------
# legacy tuner integration — thin delegates to the generic API
# ---------------------------------------------------------------------------

def make_tuner(M: int, N: int, K: int, *, evaluator=None,
               profile: DeviceProfile = TPU_V5E, interpret: bool = True,
               extended_space: bool = False, seed: int = 0) -> Tuner:
    """A ready-to-run Tuner for this GEMM shape (the paper's case study 2)."""
    return Tuner.from_tunable(GEMM, _shape(M, N, K), evaluator=evaluator,
                              profile=profile, interpret=interpret,
                              extended_space=extended_space)


def tune_matmul(M: int, N: int, K: int, strategy: str = "annealing",
                budget: int = 100, profile: DeviceProfile = TPU_V5E,
                record: bool = True, seed: int = 0, **kwargs):
    from ...tune.api import tune_kernel
    return tune_kernel(GEMM, _shape(M, N, K), strategy=strategy,
                       budget=budget, profile=profile, record=record,
                       seed=seed, **kwargs)
