"""jit'd public entry point for the tuned GEMM.

``matmul(a, b)`` consults the tuned-config database (written by the tuner,
keyed by shape and device profile — CLTune scenario 3) and falls back to a
heuristic default.  ``tune_matmul`` runs the paper's search on the kernel.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ...core import (KernelSpec, TPUAnalyticalEvaluator, Tuner,
                     TuningCache, WallClockEvaluator, default_cache)
from ...core.profiles import DeviceProfile, TPU_V5E
from ...core.space import Config
from . import ref
from .matmul import (DEFAULT_CONFIG, analytical_time, make_matmul,
                     vmem_footprint)

KERNEL_NAME = "gemm"


def shape_key(M: int, N: int, K: int, dtype="float32") -> str:
    return f"M{M}_N{N}_K{K}_{jnp.dtype(dtype).name}"


def heuristic_config(M: int, N: int, K: int) -> Dict[str, Any]:
    """Largest aligned blocks that divide the problem; sensible defaults."""
    def pick(d, cands):
        for c in cands:
            if d % c == 0:
                return c
        return d
    return {
        "BLOCK_M": pick(M, (512, 256, 128, 64, 32, 16, 8)),
        "BLOCK_N": pick(N, (512, 256, 128, 64, 32, 16, 8)),
        "BLOCK_K": pick(K, (512, 256, 128, 64, 32, 16, 8)),
        "GRID_ORDER": "mn", "INNER_STEPS": 1,
        "ACC_DTYPE": "float32", "ACC_IN_OUTPUT": False, "TRANS_A": False,
    }


def lookup_config(M: int, N: int, K: int,
                  profile: DeviceProfile = TPU_V5E,
                  cache: Optional[TuningCache] = None) -> Dict[str, Any]:
    cache = cache or default_cache()
    entry = cache.get(KERNEL_NAME, shape_key(M, N, K), profile.name)
    if entry is not None:
        return dict(entry.config)
    return heuristic_config(M, N, K)


def matmul(a: jax.Array, b: jax.Array, config: Optional[Dict[str, Any]] = None,
           *, alpha: float = 1.0, beta: float = 0.0,
           c: Optional[jax.Array] = None,
           profile: DeviceProfile = TPU_V5E, interpret: bool = False):
    """C = alpha * op(A) @ B (+ beta * C), Pallas-tiled.

    The alpha/beta epilogue runs in XLA (it fuses); the Pallas kernel does
    the FLOP-heavy product, as in the paper's GEMM.
    """
    trans = bool((config or {}).get("TRANS_A", False))
    M = a.shape[1] if trans else a.shape[0]
    K = a.shape[0] if trans else a.shape[1]
    N = b.shape[1]
    cfg = config or lookup_config(M, N, K, profile)
    fn = make_matmul(M, N, K, cfg, out_dtype=a.dtype, interpret=interpret)
    out = fn(a, b)
    if alpha != 1.0:
        out = alpha * out
    if c is not None and beta != 0.0:
        out = out + beta * c
    return out


# ---------------------------------------------------------------------------
# tuner integration
# ---------------------------------------------------------------------------

def tuning_space(extended: bool = False):
    """(values, constraints) for the GEMM space.

    ``extended=True`` is the paper-scale space (>200k configurations,
    benchmark Fig. 7); the compact space is what tests sweep with real
    Pallas-interpret execution.
    """
    if extended:
        params = {
            "BLOCK_M": (32, 64, 128, 256, 512, 1024),
            "BLOCK_N": (32, 64, 128, 256, 512, 1024),
            "BLOCK_K": (32, 64, 128, 256, 512, 1024),
            "GRID_ORDER": ("mn", "nm"),
            "INNER_STEPS": (1, 2, 4, 8),
            "ACC_DTYPE": ("float32", "bfloat16"),
            "ACC_IN_OUTPUT": (False, True),
            "TRANS_A": (False, True),
            "PIPELINE_DEPTH": (2, 3, 4),
            "NBUF_OUT": (1, 2),
            "PACK": (1, 2, 4),
        }
    else:
        params = {
            "BLOCK_M": (128, 256, 512),
            "BLOCK_N": (128, 256, 512),
            "BLOCK_K": (128, 256, 512),
            "GRID_ORDER": ("mn", "nm"),
            "INNER_STEPS": (1, 2),
            "ACC_DTYPE": ("float32",),
            "ACC_IN_OUTPUT": (False, True),
            "TRANS_A": (False,),
        }
    constraints = [
        (lambda bk, s: bk % s == 0, ("BLOCK_K", "INNER_STEPS"),
         "BLOCK_K divisible by INNER_STEPS"),
        (lambda acc_out, acc: (not acc_out) or acc == "float32",
         ("ACC_IN_OUTPUT", "ACC_DTYPE"), "in-place acc requires f32"),
    ]
    return params, constraints


def make_tuner(M: int, N: int, K: int, *, evaluator=None,
               profile: DeviceProfile = TPU_V5E, interpret: bool = True,
               extended_space: bool = False, seed: int = 0) -> Tuner:
    """A ready-to-run Tuner for this GEMM shape (the paper's case study 2)."""
    evaluator = evaluator or TPUAnalyticalEvaluator(profile=profile)

    def build(cfg: Config):
        return make_matmul(M, N, K, cfg, interpret=interpret)

    def make_args(rng: np.random.Generator):
        a = jnp.asarray(rng.normal(size=(M, K)), jnp.float32)
        b = jnp.asarray(rng.normal(size=(K, N)), jnp.float32)
        return a, b

    def arg_specs():
        return (jax.ShapeDtypeStruct((M, K), jnp.float32),
                jax.ShapeDtypeStruct((K, N), jnp.float32))

    tuner = Tuner(evaluator=evaluator, profile=profile)
    tuner.set_reference(lambda a, b: ref.gemm_reference(a, b))
    tuner.add_kernel(
        build, name=KERNEL_NAME, make_args=make_args, arg_specs=arg_specs,
        analytical_model=lambda cfg, prof: analytical_time(cfg, prof, M, N, K),
        vmem_footprint=vmem_footprint,
        meta={"M": M, "N": N, "K": K})
    params, constraints = tuning_space(extended=extended_space)
    for name, values in params.items():
        tuner.add_parameter(name, values)
    for fn, names, label in constraints:
        tuner.add_constraint(fn, names, label)
    # problem-size divisibility (device-independent feasibility)
    tuner.add_constraint(lambda bm: M % bm == 0, ("BLOCK_M",), "M % BLOCK_M")
    tuner.add_constraint(lambda bn: N % bn == 0, ("BLOCK_N",), "N % BLOCK_N")
    tuner.add_constraint(lambda bk: K % bk == 0, ("BLOCK_K",), "K % BLOCK_K")
    return tuner


def tune_matmul(M: int, N: int, K: int, strategy: str = "annealing",
                budget: int = 100, profile: DeviceProfile = TPU_V5E,
                record: bool = True, seed: int = 0, **kwargs):
    tuner = make_tuner(M, N, K, profile=profile, **kwargs)
    outcome = tuner.tune(strategy=strategy, budget=budget, seed=seed,
                         record_to_cache=record,
                         shape_key=shape_key(M, N, K))
    return outcome
