"""Pallas TPU kernels — the paper's two case studies + one extension.

  matmul/     GEMM          (paper section VI)
  conv2d/     2D convolution (paper section V)
  attention/  flash attention (beyond paper; same tuning methodology)

Each package ships <name>.py (pl.pallas_call + BlockSpec), ops.py (a
``@tunable`` declaration + public op resolving configs via
``repro.core.registry.lookup``) and ref.py (pure-jnp oracle).  Importing
this package registers all three kernels in the tunable registry.
"""

from . import attention, conv2d, matmul

__all__ = ["attention", "conv2d", "matmul"]
