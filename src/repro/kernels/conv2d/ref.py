"""Pure-jnp oracle for the 2D-convolution case study (paper section V).

B[x,y] = w * sum_{i,j} F[i,j] * A[x+i-hx, y+j-hy]   (zero padding at borders)

Single-channel, single-precision, same-size output — exactly the paper's
deep-learning-style 2D convolution.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def conv2d_reference(image: jnp.ndarray, filt: jnp.ndarray,
                     weight: float = 1.0) -> jnp.ndarray:
    """image: (H, W) f32; filt: (Fh, Fw) f32; returns (H, W)."""
    h, w = image.shape
    fh, fw = filt.shape
    img = image[jnp.newaxis, jnp.newaxis]          # NCHW
    ker = filt[jnp.newaxis, jnp.newaxis]           # OIHW
    out = lax.conv_general_dilated(
        img, ker,
        window_strides=(1, 1),
        padding=((fh // 2, (fh - 1) // 2), (fw // 2, (fw - 1) // 2)),
        dimension_numbers=("NCHW", "OIHW", "NCHW"))
    return (weight * out[0, 0]).astype(image.dtype)


def conv_flops(H: int, W: int, Fh: int, Fw: int) -> float:
    """Paper footnote 2: GFLOPS computed as (1 + 2*Xf*Yf) * X * Y / t."""
    return (1.0 + 2.0 * Fh * Fw) * H * W


def conv_bytes(H: int, W: int, elt_bytes: int = 4) -> float:
    """Paper footnote 2: bandwidth as 2 * X * Y (read + write) / t."""
    return 2.0 * H * W * elt_bytes
