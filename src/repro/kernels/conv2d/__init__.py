from .conv2d import (DEFAULT_CONFIG, analytical_time, make_conv2d,
                     validate_config, vmem_footprint)
from .ops import (CONV2D, conv2d, heuristic_config, lookup_config,
                  make_tuner, shape_key, tune_conv2d, tuning_space)
from .ref import conv2d_reference, conv_bytes, conv_flops

__all__ = [
    "CONV2D", "DEFAULT_CONFIG", "analytical_time", "make_conv2d",
    "validate_config", "vmem_footprint", "conv2d", "heuristic_config",
    "lookup_config", "make_tuner", "shape_key", "tune_conv2d",
    "tuning_space", "conv2d_reference", "conv_bytes", "conv_flops",
]
