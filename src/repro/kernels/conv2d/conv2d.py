"""Tunable Pallas 2D convolution — paper case study 1, TPU-native.

Parameter vocabulary (re-derivation of paper Table II; DESIGN.md §2):

  BLOCK_H / BLOCK_W      output tile per grid step      (paper: X_wg/Y_wg —
                         on TPU the VMEM tile *is* the workgroup)
  SUB_H  1|2|4|8         row-chunking of the tile body  (paper: X_wpt/Y_wpt
                         thread coarsening -> VREG working-set control)
  UNROLL True|False      unroll the filter-tap loops    (paper: UNR)
  HALO_MODE              'materialize' = stage overlapping halo tiles through
                         HBM and convolve in Pallas (paper L$=1/2: explicit
                         local-memory caching with halo); 'xla' = direct
                         lax.conv, hardware-managed caching (paper L$=0)

Analytic-only parameters (pipeline/compiler choices, used by the >3k-config
strategy benchmarks): PAD_W (sublane pad, paper PAD), PIPELINE_DEPTH.

The halo adaptation is the interesting hardware translation: OpenCL threads
cooperatively load a halo into local memory; Pallas BlockSpecs cannot
overlap, so the halo is materialised as overlapping tiles in HBM by a cheap
XLA gather and the kernel streams those tiles through VMEM.  The duplication
factor (1 + 2*hh/BH)(1 + 2*hw/BW) is the TPU form of the paper's
halo-loading overhead, and shrinks as tiles grow — same trade-off, different
memory level.
"""

from __future__ import annotations

import functools
import math
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ...core.profiles import DeviceProfile
from .ref import conv2d_reference

Config = Dict[str, Any]

DEFAULT_CONFIG: Config = {
    "BLOCK_H": 16, "BLOCK_W": 256, "SUB_H": 1, "UNROLL": True,
    "HALO_MODE": "materialize",
}


# ---------------------------------------------------------------------------
# halo-tile materialisation (the L$ caching strategy, TPU form)
# ---------------------------------------------------------------------------

def _materialise_tiles(image, bh, bw, hh, hw):
    """(H, W) -> (gh, gw, bh + 2*hh, bw + 2*hw) overlapping halo tiles."""
    H, W = image.shape
    gh, gw = -(-H // bh), -(-W // bw)
    hp, wp = gh * bh, gw * bw
    padded = jnp.pad(image, ((hh, hh + hp - H), (hw, hw + wp - W)))

    ii, jj = jnp.meshgrid(jnp.arange(gh), jnp.arange(gw), indexing="ij")

    def slice_tile(i, j):
        return lax.dynamic_slice(padded, (i * bh, j * bw),
                                 (bh + 2 * hh, bw + 2 * hw))

    tiles = jax.vmap(jax.vmap(slice_tile))(ii, jj)
    return tiles, gh, gw


# ---------------------------------------------------------------------------
# kernel body
# ---------------------------------------------------------------------------

def _conv_kernel(tile_ref, filt_ref, o_ref, *, fh: int, fw: int,
                 bh: int, bw: int, sub_h: int, unroll: bool, weight: float):
    tile = tile_ref[0, 0]                       # (bh + fh - 1, bw + fw - 1)
    filt = filt_ref[...]                        # (fh, fw)
    n_sub = bh // sub_h
    rows = []
    for s in range(n_sub):                      # paper's work-per-thread chunking
        r0 = s * sub_h
        if unroll:                              # UNR: fully unrolled taps
            acc = jnp.zeros((sub_h, bw), dtype=jnp.float32)
            for i in range(fh):
                for j in range(fw):
                    acc += filt[i, j] * lax.dynamic_slice(
                        tile, (r0 + i, j), (sub_h, bw))
            rows.append(acc)
        else:                                   # rolled tap loop
            def tap(t, acc):
                i, j = t // fw, t % fw
                f = lax.dynamic_slice(filt, (i, j), (1, 1))[0, 0]
                win = lax.dynamic_slice(tile, (r0 + i, j), (sub_h, bw))
                return acc + f * win
            acc = lax.fori_loop(0, fh * fw, tap,
                                jnp.zeros((sub_h, bw), dtype=jnp.float32))
            rows.append(acc)
    out = rows[0] if n_sub == 1 else jnp.concatenate(rows, axis=0)
    o_ref[...] = (weight * out).astype(o_ref.dtype)


# ---------------------------------------------------------------------------
# builder
# ---------------------------------------------------------------------------

def validate_config(config: Config, H: int, W: int, Fh: int, Fw: int) -> None:
    bh, bw = config["BLOCK_H"], config["BLOCK_W"]
    if config["BLOCK_H"] % config["SUB_H"]:
        raise ValueError("BLOCK_H must divide by SUB_H")
    if bh <= 0 or bw <= 0:
        raise ValueError("blocks must be positive")
    if config["HALO_MODE"] not in ("materialize", "xla"):
        raise ValueError(f"bad HALO_MODE {config['HALO_MODE']!r}")


def make_conv2d(H: int, W: int, Fh: int, Fw: int,
                config: Config | None = None, weight: float = 1.0,
                interpret: bool = False):
    """Return fn(image, filt) -> (H, W) convolved output."""
    cfg = dict(DEFAULT_CONFIG)
    cfg.update(config or {})
    validate_config(cfg, H, W, Fh, Fw)

    if cfg["HALO_MODE"] == "xla":
        # L$ = 0: no explicit staging, let XLA/hardware manage locality.
        def xla_conv(image, filt):
            return conv2d_reference(image, filt, weight=weight)
        return xla_conv

    bh, bw = cfg["BLOCK_H"], cfg["BLOCK_W"]
    hh, hw = Fh // 2, Fw // 2
    th, tw = bh + 2 * hh, bw + 2 * hw

    kernel = functools.partial(
        _conv_kernel, fh=Fh, fw=Fw, bh=bh, bw=bw, sub_h=cfg["SUB_H"],
        unroll=bool(cfg["UNROLL"]), weight=weight)

    def conv(image, filt):
        tiles, gh, gw = _materialise_tiles(image, bh, bw, hh, hw)
        kwargs: Dict[str, Any] = {}
        if not interpret:
            kwargs["compiler_params"] = pltpu.CompilerParams(
                dimension_semantics=("parallel", "parallel"))
        out = pl.pallas_call(
            kernel,
            grid=(gh, gw),
            in_specs=[
                pl.BlockSpec((1, 1, th, tw), lambda i, j: (i, j, 0, 0)),
                pl.BlockSpec((Fh, Fw), lambda i, j: (0, 0)),
            ],
            out_specs=pl.BlockSpec((bh, bw), lambda i, j: (i, j)),
            out_shape=jax.ShapeDtypeStruct((gh * bh, gw * bw), image.dtype),
            interpret=interpret,
            **kwargs)(tiles, filt)
        return out[:H, :W]

    return conv


# ---------------------------------------------------------------------------
# structural cost model
# ---------------------------------------------------------------------------

def vmem_footprint(config: Config, Fh: int, Fw: int,
                   elt_bytes: int = 4) -> int:
    cfg = dict(DEFAULT_CONFIG)
    cfg.update(config)
    if cfg["HALO_MODE"] == "xla":
        return 0
    bh, bw = cfg["BLOCK_H"], cfg["BLOCK_W"]
    depth = int(cfg.get("PIPELINE_DEPTH", 2))
    pad_w = int(cfg.get("PAD_W", 0)) * 128
    tile = (bh + Fh - 1) * (bw + Fw - 1 + pad_w) * elt_bytes
    out = bh * bw * elt_bytes
    filt = Fh * Fw * elt_bytes
    return depth * tile + 2 * out + filt


def analytical_time(config: Config, profile: DeviceProfile,
                    H: int, W: int, Fh: int, Fw: int,
                    elt_bytes: int = 4) -> float:
    """Pipeline model reproducing the paper's conv search-space shape.

    Convolution taps run on the VPU (8x128 lanes), not the MXU, so the
    compute ceiling is the VPU rate; small filters are memory-bound and big
    filters compute-bound — the paper's Fig. 6 arc.  The two HALO modes
    reproduce Table II's L$ flip: 'xla' (hardware caching) wins for 3x3,
    'materialize' (explicit staging) wins once taps dominate.
    """
    cfg = dict(DEFAULT_CONFIG)
    cfg.update(config)
    bh, bw = cfg["BLOCK_H"], cfg["BLOCK_W"]
    if bh % cfg["SUB_H"]:
        return math.inf
    flops = (1.0 + 2.0 * Fh * Fw) * H * W
    vpu_flops = profile.peak_flops / 24.0       # VPU : MXU throughput ratio

    if cfg["HALO_MODE"] == "xla":
        # generic XLA conv lowering: decent but untiled for this exact shape
        compute_t = flops / (vpu_flops * 0.45)
        memory_t = 2.0 * H * W * elt_bytes / profile.hbm_bw
        return max(compute_t, memory_t) + profile.launch_overhead

    if vmem_footprint(cfg, Fh, Fw, elt_bytes) > profile.vmem_bytes:
        return math.inf
    gh, gw = -(-H // bh), -(-W // bw)
    # VPU efficiency: lane alignment of the minor dim, sublane of rows
    lane_eff = bw / (math.ceil(bw / 128) * 128)
    sub_eff = min(1.0, cfg["SUB_H"] * bh / (math.ceil(bh / 8) * 8) / bh * 8) \
        if bh < 8 else 1.0
    unroll_gain = 1.0 if cfg["UNROLL"] else 0.72   # rolled taps re-slice filter
    subh_pen = 1.0 + 0.02 * max(0, int(math.log2(max(cfg["SUB_H"], 1))))
    eff = 0.85 * lane_eff * sub_eff * unroll_gain / subh_pen
    compute_t = flops / (vpu_flops * eff)

    dup = (1.0 + (Fh - 1) / bh) * (1.0 + (Fw - 1) / bw)
    # read image + write tiles + read tiles + write out
    traffic = H * W * elt_bytes * (1.0 + 2.0 * dup + 1.0)
    memory_t = traffic / profile.hbm_bw

    depth = int(cfg.get("PIPELINE_DEPTH", 2))
    overlap = {2: 1.0, 3: 0.97, 4: 0.96}.get(depth, 1.0)
    bubble_t = gh * gw * profile.grid_step_overhead / depth
    return max(compute_t, memory_t * overlap) + bubble_t \
        + profile.launch_overhead
