"""jit'd entry point + tuner integration for the conv2d case study."""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ...core import TPUAnalyticalEvaluator, Tuner, TuningCache, default_cache
from ...core.profiles import DeviceProfile, TPU_V5E
from ...core.space import Config
from .conv2d import (DEFAULT_CONFIG, analytical_time, make_conv2d,
                     vmem_footprint)
from .ref import conv2d_reference

KERNEL_NAME = "conv2d"


def shape_key(H: int, W: int, Fh: int, Fw: int) -> str:
    return f"H{H}_W{W}_F{Fh}x{Fw}"


def heuristic_config(H: int, W: int, Fh: int, Fw: int) -> Dict[str, Any]:
    return {"BLOCK_H": min(16, H), "BLOCK_W": min(256, W),
            "SUB_H": 1, "UNROLL": True, "HALO_MODE": "materialize"}


def lookup_config(H: int, W: int, Fh: int, Fw: int,
                  profile: DeviceProfile = TPU_V5E,
                  cache: Optional[TuningCache] = None) -> Dict[str, Any]:
    cache = cache or default_cache()
    entry = cache.get(KERNEL_NAME, shape_key(H, W, Fh, Fw), profile.name)
    return dict(entry.config) if entry else heuristic_config(H, W, Fh, Fw)


def conv2d(image: jax.Array, filt: jax.Array,
           config: Optional[Dict[str, Any]] = None, weight: float = 1.0,
           profile: DeviceProfile = TPU_V5E, interpret: bool = False):
    H, W = image.shape
    Fh, Fw = filt.shape
    cfg = config or lookup_config(H, W, Fh, Fw, profile)
    return make_conv2d(H, W, Fh, Fw, cfg, weight=weight,
                       interpret=interpret)(image, filt)


# ---------------------------------------------------------------------------
# tuner integration
# ---------------------------------------------------------------------------

def tuning_space(extended: bool = False):
    """Conv parameter space (compare paper Table II: 3424 configurations)."""
    if extended:
        params = {
            "BLOCK_H": (4, 8, 16, 32, 64, 128),
            "BLOCK_W": (64, 128, 256, 512, 1024),
            "SUB_H": (1, 2, 4, 8),
            "UNROLL": (True, False),
            "HALO_MODE": ("materialize", "xla"),
            "PAD_W": (0, 1),
            "PIPELINE_DEPTH": (2, 3, 4),
        }
    else:
        params = {
            "BLOCK_H": (8, 16, 32),
            "BLOCK_W": (128, 256),
            "SUB_H": (1, 2),
            "UNROLL": (True, False),
            "HALO_MODE": ("materialize", "xla"),
        }
    constraints = [
        (lambda bh, s: bh % s == 0, ("BLOCK_H", "SUB_H"),
         "BLOCK_H divisible by SUB_H"),
    ]
    return params, constraints


def make_tuner(H: int, W: int, Fh: int, Fw: int, *, evaluator=None,
               profile: DeviceProfile = TPU_V5E, interpret: bool = True,
               extended_space: bool = True) -> Tuner:
    evaluator = evaluator or TPUAnalyticalEvaluator(profile=profile)

    def build(cfg: Config):
        return make_conv2d(H, W, Fh, Fw, cfg, interpret=interpret)

    def make_args(rng: np.random.Generator):
        img = jnp.asarray(rng.normal(size=(H, W)), jnp.float32)
        flt = jnp.asarray(rng.normal(size=(Fh, Fw)), jnp.float32)
        return img, flt

    def arg_specs():
        return (jax.ShapeDtypeStruct((H, W), jnp.float32),
                jax.ShapeDtypeStruct((Fh, Fw), jnp.float32))

    tuner = Tuner(evaluator=evaluator, profile=profile)
    tuner.set_reference(conv2d_reference)
    tuner.add_kernel(
        build, name=KERNEL_NAME, make_args=make_args, arg_specs=arg_specs,
        analytical_model=lambda cfg, prof: analytical_time(
            cfg, prof, H, W, Fh, Fw),
        vmem_footprint=lambda cfg: vmem_footprint(cfg, Fh, Fw),
        meta={"H": H, "W": W, "Fh": Fh, "Fw": Fw})
    params, constraints = tuning_space(extended=extended_space)
    for name, values in params.items():
        tuner.add_parameter(name, values)
    for fn, names, label in constraints:
        tuner.add_constraint(fn, names, label)
    return tuner


def tune_conv2d(H: int, W: int, Fh: int, Fw: int,
                strategy: str = "annealing", budget: int = 107,
                profile: DeviceProfile = TPU_V5E, record: bool = True,
                seed: int = 0, **kwargs):
    """Paper section V-B used budget=107 (1/32 of its 3424-config space)."""
    tuner = make_tuner(H, W, Fh, Fw, profile=profile, **kwargs)
    return tuner.tune(strategy=strategy, budget=budget, seed=seed,
                      record_to_cache=record,
                      shape_key=shape_key(H, W, Fh, Fw))
