"""Public entry point + tunable declaration for the conv2d case study."""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ...core import SearchSpace, Tuner, TuningCache
from ...core.profiles import DeviceProfile, TPU_V5E
from ...core.registry import AutotunePolicy, Shape, lookup, tunable
from ...core.space import Config
from .conv2d import (analytical_time, make_conv2d,
                     vmem_footprint)
from .ref import conv2d_reference

KERNEL_NAME = "conv2d"


def _shape(H: int, W: int, Fh: int, Fw: int) -> Dict[str, Any]:
    return {"H": H, "W": W, "Fh": Fh, "Fw": Fw}


def shape_key(H: int, W: int, Fh: int, Fw: int) -> str:
    return f"H{H}_W{W}_F{Fh}x{Fw}"


def heuristic_config(H: int, W: int, Fh: int, Fw: int) -> Dict[str, Any]:
    # tiny images make min(...) fall outside the declared value lists;
    # the registry's project_feasible snaps those to the nearest in-space
    # values before the config is served
    return {"BLOCK_H": min(16, H), "BLOCK_W": min(256, W),
            "SUB_H": 1, "UNROLL": True, "HALO_MODE": "materialize"}


def tuning_space(extended: bool = False):
    """Conv parameter space (compare paper Table II: 3424 configurations)."""
    if extended:
        params = {
            "BLOCK_H": (4, 8, 16, 32, 64, 128),
            "BLOCK_W": (64, 128, 256, 512, 1024),
            "SUB_H": (1, 2, 4, 8),
            "UNROLL": (True, False),
            "HALO_MODE": ("materialize", "xla"),
            "PAD_W": (0, 1),
            "PIPELINE_DEPTH": (2, 3, 4),
        }
    else:
        params = {
            "BLOCK_H": (8, 16, 32),
            "BLOCK_W": (128, 256),
            "SUB_H": (1, 2),
            "UNROLL": (True, False),
            "HALO_MODE": ("materialize", "xla"),
        }
    constraints = [
        (lambda bh, s: bh % s == 0, ("BLOCK_H", "SUB_H"),
         "BLOCK_H divisible by SUB_H"),
    ]
    return params, constraints


def _space(shape: Shape, extended: bool = True) -> SearchSpace:
    params, constraints = tuning_space(extended=extended)
    sp = SearchSpace()
    for name, values in params.items():
        sp.add_parameter(name=name, values=values)
    for fn, names, label in constraints:
        sp.add_constraint(fn, names, label)
    return sp


def _make_args(shape: Shape, rng: np.random.Generator):
    H, W, Fh, Fw = shape["H"], shape["W"], shape["Fh"], shape["Fw"]
    img = jnp.asarray(rng.normal(size=(H, W)), jnp.float32)
    flt = jnp.asarray(rng.normal(size=(Fh, Fw)), jnp.float32)
    return img, flt


def _arg_specs(shape: Shape):
    H, W, Fh, Fw = shape["H"], shape["W"], shape["Fh"], shape["Fw"]
    return (jax.ShapeDtypeStruct((H, W), jnp.float32),
            jax.ShapeDtypeStruct((Fh, Fw), jnp.float32))


def _elt_bytes(shape: Shape) -> int:
    """Image element width from the shape's dtype (default float32)."""
    return jnp.dtype(shape.get("dtype", "float32")).itemsize


@tunable(
    name=KERNEL_NAME,
    space=_space,
    heuristic=lambda s: heuristic_config(s["H"], s["W"], s["Fh"], s["Fw"]),
    shape_key=lambda s: shape_key(s["H"], s["W"], s["Fh"], s["Fw"]),
    make_args=_make_args,
    arg_specs=_arg_specs,
    # dtype threads through model and footprint with the same element
    # width so static VMEM proofs agree with the analytical cliff
    analytical_model=lambda s, cfg, prof: analytical_time(
        cfg, prof, s["H"], s["W"], s["Fh"], s["Fw"],
        elt_bytes=_elt_bytes(s)),
    vmem_footprint=lambda s, cfg: vmem_footprint(
        cfg, s["Fh"], s["Fw"], elt_bytes=_elt_bytes(s)),
    reference=lambda s: conv2d_reference,
    default_shapes=(_shape(4096, 4096, 3, 3),),
    # paper V-B: budget 107 = 1/32 of the 3424-config EXTENDED space, so
    # registry-driven tuning must search that space too
    defaults={"strategy": "annealing", "budget": 107, "extended_space": True},
    tags=("paper-case-study", "conv"))
def CONV2D(shape: Shape, config: Config, *, interpret: bool = False):
    """The paper's section V case study: 2D convolution."""
    return make_conv2d(shape["H"], shape["W"], shape["Fh"], shape["Fw"],
                       config, interpret=interpret)


def lookup_config(H: int, W: int, Fh: int, Fw: int,
                  profile: DeviceProfile = TPU_V5E,
                  cache: Optional[TuningCache] = None,
                  policy: "AutotunePolicy | str | None" = None
                  ) -> Dict[str, Any]:
    return lookup(CONV2D, _shape(H, W, Fh, Fw), profile=profile, cache=cache,
                  policy=policy)


def conv2d(image: jax.Array, filt: jax.Array,
           config: Optional[Dict[str, Any]] = None, weight: float = 1.0,
           profile: DeviceProfile = TPU_V5E, interpret: bool = False,
           policy: "AutotunePolicy | str | None" = None):
    H, W = image.shape
    Fh, Fw = filt.shape
    cfg = config or lookup_config(H, W, Fh, Fw, profile, policy=policy)
    return make_conv2d(H, W, Fh, Fw, cfg, weight=weight,
                       interpret=interpret)(image, filt)


# ---------------------------------------------------------------------------
# legacy tuner integration — thin delegates to the generic API
# ---------------------------------------------------------------------------

def make_tuner(H: int, W: int, Fh: int, Fw: int, *, evaluator=None,
               profile: DeviceProfile = TPU_V5E, interpret: bool = True,
               extended_space: bool = True) -> Tuner:
    return Tuner.from_tunable(CONV2D, _shape(H, W, Fh, Fw),
                              evaluator=evaluator, profile=profile,
                              interpret=interpret,
                              extended_space=extended_space)


def tune_conv2d(H: int, W: int, Fh: int, Fw: int,
                strategy: str = "annealing", budget: int = 107,
                profile: DeviceProfile = TPU_V5E, record: bool = True,
                seed: int = 0, **kwargs):
    """Paper section V-B used budget=107 (1/32 of its 3424-config space)."""
    from ...tune.api import tune_kernel
    kwargs.setdefault("extended_space", True)
    return tune_kernel(CONV2D, _shape(H, W, Fh, Fw), strategy=strategy,
                       budget=budget, profile=profile, record=record,
                       seed=seed, **kwargs)
