"""Checkpointing: atomic, async, retention-managed, mesh-elastic.

Layout (one directory per step):

    <dir>/step_000123/
        manifest.json       # tree structure, shapes, dtypes, step, config
        arrays.npz          # flat path -> ndarray

Durability discipline:
  * writes go to ``step_XXXXXX.tmp`` then os.replace -> crash-safe (a torn
    write never shadows a good checkpoint);
  * ``latest_step`` scans for *complete* directories only (manifest present);
  * async mode hands the (host-transferred) arrays to a writer thread so the
    train loop is not blocked by disk I/O;
  * restore works onto ANY mesh: arrays are saved unsharded (global view)
    and re-placed with the target sharding on load — elastic re-scaling.

Restore-with-resharding at 1000-node scale would write per-shard files with
a global index; the manifest format carries the metadata needed for that
(shapes/dtypes/paths) so the storage layer can swap in without touching
callers.
"""

from __future__ import annotations

import dataclasses
import json
import os
import re
import shutil
import threading
import time
from typing import Any, Dict, List, Optional

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d{6,})$")


def _flatten(tree: Any, prefix: str = "") -> Dict[str, Any]:
    out: Dict[str, Any] = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}/{k}" if prefix else k))
        return out
    if isinstance(tree, (tuple, list)) or hasattr(tree, "_fields"):
        seq = tuple(tree)
        for i, v in enumerate(seq):
            out.update(_flatten(v, f"{prefix}/#{i}" if prefix else f"#{i}"))
        return out
    out[prefix or "value"] = tree
    return out


def _unflatten_into(template: Any, flat: Dict[str, Any],
                    prefix: str = "") -> Any:
    if isinstance(template, dict):
        return {k: _unflatten_into(template[k], flat,
                                   f"{prefix}/{k}" if prefix else k)
                for k in template}
    if hasattr(template, "_fields"):               # NamedTuple
        vals = [_unflatten_into(v, flat,
                                f"{prefix}/#{i}" if prefix else f"#{i}")
                for i, v in enumerate(tuple(template))]
        return type(template)(*vals)
    if isinstance(template, (tuple, list)):
        vals = [_unflatten_into(v, flat,
                                f"{prefix}/#{i}" if prefix else f"#{i}")
                for i, v in enumerate(template)]
        return type(template)(vals)
    return flat[prefix or "value"]


@dataclasses.dataclass
class CheckpointManager:
    directory: str
    keep: int = 3
    async_save: bool = False

    def __post_init__(self):
        os.makedirs(self.directory, exist_ok=True)
        self._writer: Optional[threading.Thread] = None
        self._error: Optional[Exception] = None

    # -- inventory -----------------------------------------------------------
    def steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.directory):
            m = _STEP_RE.match(name)
            if m and os.path.exists(os.path.join(self.directory, name,
                                                 "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def _path(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:06d}")

    # -- save -------------------------------------------------------------------
    def save(self, step: int, tree: Any,
             extra: Optional[Dict[str, Any]] = None,
             block: bool = True) -> None:
        """Checkpoint ``tree`` (pytree of arrays) at ``step``."""
        self.wait()                                   # one writer at a time
        flat = _flatten(tree)
        # device -> host transfer happens here (the synchronous part);
        # disk I/O can then go async.  Narrow float dtypes (bfloat16, fp8)
        # are not native numpy types: store them widened to float32 — an
        # exact (lossless) embedding — and record the true dtype in the
        # manifest for bit-exact restore.
        host, dtypes = {}, {}
        for k, v in flat.items():
            a = np.asarray(v)
            dtypes[k] = str(a.dtype)
            if a.dtype.kind == "V" or str(a.dtype) in (
                    "bfloat16", "float8_e4m3fn", "float8_e5m2"):
                a = a.astype(np.float32)
            host[k] = a
        manifest = {
            "step": step,
            "time": time.time(),
            "arrays": {k: {"shape": list(v.shape), "dtype": dtypes[k]}
                       for k, v in host.items()},
            "extra": extra or {},
        }

        def write():
            try:
                final = self._path(step)
                tmp = final + ".tmp"
                if os.path.exists(tmp):
                    shutil.rmtree(tmp)
                os.makedirs(tmp)
                np.savez(os.path.join(tmp, "arrays.npz"), **host)
                with open(os.path.join(tmp, "manifest.json"), "w") as f:
                    json.dump(manifest, f, indent=2)
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.replace(tmp, final)                # atomic publish
                self._gc()
            except Exception as e:  # noqa: BLE001
                self._error = e

        if self.async_save and not block:
            self._writer = threading.Thread(target=write, daemon=True)
            self._writer.start()
        else:
            write()
            self._raise_pending()

    def wait(self) -> None:
        if self._writer is not None:
            self._writer.join()
            self._writer = None
        self._raise_pending()

    def _raise_pending(self):
        if self._error is not None:
            e, self._error = self._error, None
            raise RuntimeError(f"async checkpoint write failed: {e}") from e

    def _gc(self):
        steps = self.steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self._path(s), ignore_errors=True)

    # -- restore ----------------------------------------------------------------
    def restore(self, step: Optional[int] = None, template: Any = None,
                shardings: Any = None) -> Dict[str, Any]:
        """Load a checkpoint.

        ``template`` (pytree) reconstructs structure; ``shardings`` (pytree
        of NamedSharding, same structure) re-places arrays onto the target
        mesh — restoring onto a different mesh than the one that saved is
        supported (elastic re-scaling).
        Returns {"step", "tree", "extra"}.
        """
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        path = self._path(step)
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        with np.load(os.path.join(path, "arrays.npz")) as z:
            flat = {}
            for k in z.files:
                a = z[k]
                want = manifest["arrays"].get(k, {}).get("dtype")
                if want and str(a.dtype) != want:
                    a = a.astype(jax.numpy.dtype(want))   # bf16/fp8 restore
                flat[k] = a
        if template is None:
            tree = flat
        else:
            tree = _unflatten_into(template, flat)
            if shardings is not None:
                tree = jax.tree_util.tree_map(
                    lambda a, s: jax.device_put(a, s), tree, shardings)
        return {"step": manifest["step"], "tree": tree,
                "extra": manifest.get("extra", {})}

    def verify(self, step: int) -> bool:
        """Integrity check: manifest arrays all present with right shapes."""
        path = self._path(step)
        try:
            with open(os.path.join(path, "manifest.json")) as f:
                manifest = json.load(f)
            with np.load(os.path.join(path, "arrays.npz")) as z:
                for k, meta in manifest["arrays"].items():
                    if k not in z.files:
                        return False
                    if list(z[k].shape) != meta["shape"]:
                        return False
            return True
        except Exception:  # noqa: BLE001
            return False
