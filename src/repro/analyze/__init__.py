"""repro.analyze — static search-space & declaration analysis.

CLTune §III-A auto-imposes device limits as search-space constraints;
this package is that idea grown into a static-analysis pass over the
whole `@tunable` layer:

* :mod:`~repro.analyze.space_audit` — satisfiability, dead values,
  constraint health (exact below a cardinality bound, stratified above
  it, with an explicit ``exact|probabilistic`` confidence verdict);
* :mod:`~repro.analyze.resource` — the declared ``vmem_footprint``
  model evaluated against ``DeviceProfile`` budgets: **proven**
  infeasibility the engine answers without compiling
  (``EngineStats.proven_pruned``) and the lookup chain refuses to
  transfer;
* :mod:`~repro.analyze.lint` — registry-wide declaration rules, each a
  typed :class:`Finding` with a stable ``rule_id``;
* ``python -m repro.analyze`` — the CLI/CI entry point.

Env knobs (see :mod:`repro.core.envknobs` conventions):

* ``REPRO_ANALYZE`` — default for ``Tuner.tune(analyze=...)`` /
  ``tune_kernel(analyze=...)`` when the caller passes nothing
  (default off; non-boolean values raise).
* ``REPRO_ANALYZE_STRICT`` — when analysis runs pre-search, raise on
  error-severity findings instead of tuning anyway (default off).
"""

from __future__ import annotations

from ..core.envknobs import env_bool
from .findings import SEVERITIES, AnalysisReport, Finding
from .lint import (analyze_registry, constraint_arity_error, kernel_findings,
                   render_text)
from .resource import (alignment_findings, device_constraints,
                       dtype_bytes, footprint_bytes,
                       install_device_constraints, proven_checker,
                       proven_violations, resource_findings)
from .space_audit import (DEFAULT_EXACT_LIMIT, DEFAULT_SAMPLES, SpaceReport,
                          audit_space, space_findings)


def analyze_default() -> bool:
    """Session default for ``analyze=`` knobs (``REPRO_ANALYZE``)."""
    return env_bool("REPRO_ANALYZE", False)


def strict_default() -> bool:
    """Whether pre-search analysis raises on errors
    (``REPRO_ANALYZE_STRICT``)."""
    return env_bool("REPRO_ANALYZE_STRICT", False)


__all__ = [
    "AnalysisReport", "Finding", "SEVERITIES", "SpaceReport",
    "alignment_findings", "analyze_default", "analyze_registry",
    "audit_space", "constraint_arity_error", "device_constraints",
    "dtype_bytes", "footprint_bytes", "install_device_constraints",
    "kernel_findings", "proven_checker", "proven_violations",
    "render_text", "resource_findings", "space_findings",
    "strict_default", "DEFAULT_EXACT_LIMIT", "DEFAULT_SAMPLES",
]
