"""Static resource checker: CLTune §III-A device limits, proven offline.

The paper queries the device for its limits (max workgroup size, local
memory bytes) and auto-imposes them as search-space constraints so
illegal configs are never launched.  The TPU analogue: a kernel's
declared ``vmem_footprint(shape, config) -> bytes`` model evaluated
against ``DeviceProfile.vmem_bytes``.  A config whose declared
footprint exceeds the device budget is **proven infeasible** — the
engine can answer it as an ``inf`` trial without compiling, the lookup
chain can refuse to transfer it, and no survivor-fraction hedge is
needed (unlike PR 9's *predicted* pruning, a proof cannot be wrong
about more than the declaration itself).

MXU-tile and VPU-sublane alignment are checked too, but only as
advisory findings: a misaligned block is slow (padding), not illegal,
so making it a hard constraint would change search winners.
"""

from __future__ import annotations

from typing import (TYPE_CHECKING, Any, Callable, Dict, List, Mapping,
                    Optional, Tuple)

from ..core.profiles import DeviceProfile
from ..core.space import SearchSpace
from .findings import Finding

if TYPE_CHECKING:                                    # pragma: no cover
    from ..core.registry import TunableKernel

Shape = Mapping[str, Any]
Config = Mapping[str, Any]
#: a proven checker maps a config to the list of violated limits
ProvenChecker = Callable[[Config], List[str]]

_DTYPE_BYTES = {"float32": 4, "f32": 4, "bfloat16": 2, "bf16": 2,
                "float16": 2, "f16": 2, "int8": 1, "fp8": 1,
                "float64": 8, "f64": 8}


def dtype_bytes(shape: Shape, default: int = 4) -> int:
    """Element width implied by a shape dict's ``dtype`` entry."""
    name = str(shape.get("dtype", "")).lower()
    return _DTYPE_BYTES.get(name, default)


def footprint_bytes(kernel: "TunableKernel", shape: Shape,
                    config: Config) -> Optional[int]:
    """Declared VMEM footprint of ``config`` at ``shape``, or ``None``
    when the kernel declares no model (no proof possible)."""
    if kernel.vmem_footprint is None:
        return None
    return int(kernel.vmem_footprint(dict(shape), dict(config)))


def proven_violations(kernel: "TunableKernel", shape: Shape, config: Config,
                      profile: DeviceProfile) -> List[str]:
    """Device limits ``config`` provably violates at ``shape``.

    Empty list means "no proof of infeasibility" — it does NOT mean the
    config is feasible.  A footprint model that raises yields no proof
    (the declaration bug is the linter's job, not the prune path's).
    """
    try:
        fp = footprint_bytes(kernel, shape, config)
    except Exception:
        return []
    if fp is not None and not profile.fits_vmem(fp):
        return [f"vmem: declared footprint {fp} B > {profile.vmem_bytes} B "
                f"on {profile.name}"]
    return []


def proven_checker(kernel: "TunableKernel", shape: Shape,
                   profile: DeviceProfile) -> Optional[ProvenChecker]:
    """Engine-attachable checker, or ``None`` if no footprint model."""
    if kernel.vmem_footprint is None:
        return None
    frozen = dict(shape)

    def check(config: Config) -> List[str]:
        return proven_violations(kernel, frozen, config, profile)

    return check


def device_constraints(
        kernel: "TunableKernel", shape: Shape, profile: DeviceProfile,
        names: Tuple[str, ...]
) -> List[Tuple[Callable[..., bool], Tuple[str, ...], str]]:
    """Auto-imposed constraints, CLTune §III-A style.

    Returns ``(fn, names, label)`` triples ready for
    ``SearchSpace.add_constraint``, spanning the given parameter
    ``names``.  Only *proof* rules become constraints (the VMEM
    budget); alignment stays advisory because a padded tile is legal.
    """
    checker = proven_checker(kernel, shape, profile)
    if checker is None:
        return []

    def fits(*values: object) -> bool:
        return not checker(dict(zip(names, values)))

    label = f"analyze:vmem<={profile.vmem_bytes}B@{profile.name}"
    return [(fits, tuple(names), label)]


def install_device_constraints(space: SearchSpace, kernel: "TunableKernel",
                               shape: Shape,
                               profile: DeviceProfile) -> int:
    """Add the proven device constraints to ``space``; returns count."""
    triples = device_constraints(kernel, shape, profile, space.names)
    for fn, names, label in triples:
        space.add_constraint(fn, names, label=label)
    return len(triples)


def alignment_findings(kernel: "TunableKernel", shape: Shape, config: Config,
                       profile: DeviceProfile, *,
                       context: str = "heuristic") -> List[Finding]:
    """Advisory MXU/sublane alignment report for one config.

    Flags integer block-like parameters (``BLOCK_*``) that are not a
    multiple of the dtype's sublane tile — such tiles get padded by the
    Mosaic layout pass, wasting VPU lanes.  Info severity: legal, just
    suspicious.
    """
    sub = profile.sublanes(dtype_bytes(shape))
    out: List[Finding] = []
    for name, value in config.items():
        if not name.startswith("BLOCK"):
            continue
        if isinstance(value, bool) or not isinstance(value, int):
            continue
        if value % sub:
            out.append(Finding(
                rule_id="align-sublane", severity="info",
                kernel=kernel.name, shape=dict(shape),
                profile=profile.name,
                detail=f"{context} {name}={value} is not a multiple of the "
                       f"{sub}-row sublane tile on {profile.name} "
                       f"(padded, wasted lanes)",
                data={"param": name, "value": value, "sublanes": sub,
                      "context": context}))
        elif value % profile.mxu_dim and value > profile.mxu_dim:
            out.append(Finding(
                rule_id="align-mxu", severity="info",
                kernel=kernel.name, shape=dict(shape),
                profile=profile.name,
                detail=f"{context} {name}={value} is not a multiple of the "
                       f"{profile.mxu_dim}-wide MXU tile",
                data={"param": name, "value": value,
                      "mxu_dim": profile.mxu_dim, "context": context}))
    return out


def resource_findings(kernel: "TunableKernel", shape: Shape,
                      profile: DeviceProfile,
                      feasible_sample: List[Dict[str, Any]],
                      confidence: str) -> List[Finding]:
    """Device-feasibility findings for one (kernel, shape, profile).

    * every sampled feasible config over budget -> the whole space is
      unusable on that device: error when the sample was exhaustive,
      warning otherwise;
    * a nonzero fraction over budget -> info with the proven fraction
      (these are exactly the configs the engine will answer without
      compiling).
    """
    if kernel.vmem_footprint is None or not feasible_sample:
        return []
    over = 0
    broken = 0
    for cfg in feasible_sample:
        try:
            fp = footprint_bytes(kernel, shape, cfg)
        except Exception:
            broken += 1
            continue
        if fp is not None and not profile.fits_vmem(fp):
            over += 1
    out: List[Finding] = []
    n = len(feasible_sample)
    if broken:
        out.append(Finding(
            rule_id="footprint-model-raises", severity="error",
            kernel=kernel.name, shape=dict(shape), profile=profile.name,
            detail=f"vmem_footprint raised on {broken}/{n} feasible "
                   f"config(s); a raising model yields no proofs and no "
                   f"pruning", data={"raised": broken, "sampled": n}))
    if over == n and broken == 0:
        exact = confidence == "exact" and n < 512  # sample not truncated
        out.append(Finding(
            rule_id="space-over-vmem", severity="error" if exact
            else "warning",
            kernel=kernel.name, shape=dict(shape), profile=profile.name,
            detail=f"every {'feasible config' if exact else 'sampled config'}"
                   f" ({n}) exceeds the {profile.vmem_bytes} B VMEM budget "
                   f"on {profile.name} — the space is unusable there",
            data={"over": over, "sampled": n, "confidence": confidence}))
    elif over:
        out.append(Finding(
            rule_id="device-feasibility", severity="info",
            kernel=kernel.name, shape=dict(shape), profile=profile.name,
            detail=f"{over}/{n} sampled feasible config(s) provably exceed "
                   f"VMEM on {profile.name}; the engine answers these "
                   f"without compiling (proven_pruned)",
            data={"over": over, "sampled": n, "confidence": confidence}))
    return out
