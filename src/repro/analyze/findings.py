"""Typed findings: the analyzer's unit of output.

Every rule in :mod:`repro.analyze` — space audit, resource check,
declaration lint — reports through a :class:`Finding`: a stable
``rule_id``, a severity, the kernel it concerns and a human-readable
detail string, plus optional structured context (shape, profile, extra
data).  Findings aggregate into an :class:`AnalysisReport` that knows
how to serialize itself to machine-readable JSON and how to map
severities onto a process exit code (the ``python -m repro.analyze``
contract: nonzero on errors, ``--strict`` also fails warnings).
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, Iterator, List, Mapping, Optional, Tuple

#: Ordered severities, most severe first.  ``error`` findings always
#: fail the CLI; ``warning`` findings fail it under ``--strict``;
#: ``info`` findings are advisory statistics and never gate.
SEVERITIES: Tuple[str, ...] = ("error", "warning", "info")

REPORT_SCHEMA_VERSION = 1


@dataclasses.dataclass(frozen=True)
class Finding:
    """One analyzer diagnosis: a rule hit on a kernel/space/declaration."""

    rule_id: str
    severity: str
    kernel: str = ""
    detail: str = ""
    #: shape the finding was evaluated at (None for shape-free rules)
    shape: Optional[Dict[str, Any]] = None
    #: device-profile name for resource findings (None when device-free)
    profile: Optional[str] = None
    #: structured context for tooling (counts, offending values, labels)
    data: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def __post_init__(self) -> None:
        if not self.rule_id:
            raise ValueError("Finding.rule_id must be non-empty")
        if self.severity not in SEVERITIES:
            raise ValueError(
                f"unknown severity {self.severity!r}; expected one of "
                f"{SEVERITIES}")

    def to_json(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {
            "rule_id": self.rule_id,
            "severity": self.severity,
            "kernel": self.kernel,
            "detail": self.detail,
        }
        if self.shape is not None:
            out["shape"] = dict(self.shape)
        if self.profile is not None:
            out["profile"] = self.profile
        if self.data:
            out["data"] = dict(self.data)
        return out

    def __str__(self) -> str:
        where = self.kernel or "<space>"
        ctx = ""
        if self.profile:
            ctx += f" [{self.profile}]"
        if self.shape:
            dims = ",".join(f"{k}={v}" for k, v in self.shape.items())
            ctx += f" [{dims}]"
        return f"{self.severity:<7} {self.rule_id:<28} {where}{ctx}: {self.detail}"


class AnalysisReport:
    """An ordered collection of findings with severity accounting."""

    def __init__(self, findings: Optional[List[Finding]] = None):
        self.findings: List[Finding] = list(findings or ())

    # -- collection --------------------------------------------------------
    def add(self, finding: Finding) -> None:
        self.findings.append(finding)

    def extend(self, findings: List[Finding]) -> None:
        self.findings.extend(findings)

    # -- accounting --------------------------------------------------------
    def by_severity(self, severity: str) -> List[Finding]:
        if severity not in SEVERITIES:
            raise ValueError(f"unknown severity {severity!r}")
        return [f for f in self.findings if f.severity == severity]

    @property
    def errors(self) -> List[Finding]:
        return self.by_severity("error")

    @property
    def warnings(self) -> List[Finding]:
        return self.by_severity("warning")

    def counts(self) -> Dict[str, int]:
        out = {s: 0 for s in SEVERITIES}
        for f in self.findings:
            out[f.severity] += 1
        return out

    def exit_code(self, strict: bool = False) -> int:
        """CLI contract: 1 on errors, 1 on warnings too under strict."""
        if self.errors:
            return 1
        if strict and self.warnings:
            return 1
        return 0

    # -- serialization -----------------------------------------------------
    def to_json(self) -> Dict[str, Any]:
        return {
            "schema_version": REPORT_SCHEMA_VERSION,
            "counts": self.counts(),
            "findings": [f.to_json() for f in self.findings],
        }

    def dumps(self, indent: int = 2) -> str:
        return json.dumps(self.to_json(), indent=indent, default=str,
                          sort_keys=False)

    def summary(self) -> str:
        c = self.counts()
        return (f"{len(self.findings)} finding(s): {c['error']} error, "
                f"{c['warning']} warning, {c['info']} info")

    def __len__(self) -> int:
        return len(self.findings)

    def __iter__(self) -> Iterator[Finding]:
        return iter(self.findings)

    def __repr__(self) -> str:
        return f"AnalysisReport({self.summary()})"


def stats_dict(report: "AnalysisReport",
               extra: Optional[Mapping[str, Any]] = None) -> Dict[str, Any]:
    """Flatten a report into the stats mapping tuner outcomes attach."""
    out: Dict[str, Any] = {"findings": report.counts()}
    if extra:
        out.update(dict(extra))
    return out
