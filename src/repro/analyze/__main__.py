"""``python -m repro.analyze``: sweep the registry, exit nonzero on errors.

Human-readable findings go to stderr; the machine-readable JSON report
goes to stdout (or to ``--json PATH``), so ``python -m repro.analyze
> findings.json`` is always parseable.

Exit codes: 0 clean, 1 error-severity findings (``--strict``: also
warnings), 2 usage errors (unknown kernel/profile).
"""

from __future__ import annotations

import argparse
import sys
from typing import List, Optional

from ..core.profiles import PROFILES, get_profile
from ..core.registry import KernelRegistry
from .lint import analyze_registry, render_text
from .space_audit import DEFAULT_EXACT_LIMIT, DEFAULT_SAMPLES


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analyze",
        description="Static analyzer for @tunable declarations: space "
                    "satisfiability, device-resource proofs, lint rules.")
    ap.add_argument("--strict", action="store_true",
                    help="exit nonzero on warnings too (CI gate)")
    ap.add_argument("--kernel", action="append", default=None,
                    metavar="NAME",
                    help="restrict to this kernel (repeatable)")
    ap.add_argument("--profile", action="append", default=None,
                    metavar="NAME",
                    help=f"restrict device checks to this profile "
                         f"(repeatable; known: {', '.join(sorted(PROFILES))})")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the JSON report here instead of stdout")
    ap.add_argument("--exact-limit", type=int, default=DEFAULT_EXACT_LIMIT,
                    help="max cardinality for exact enumeration "
                         "(default %(default)s)")
    ap.add_argument("--samples", type=int, default=DEFAULT_SAMPLES,
                    help="stratified sample size above the exact limit "
                         "(default %(default)s)")
    ap.add_argument("--quiet", action="store_true",
                    help="suppress the human-readable listing on stderr")
    return ap


def main(argv: Optional[List[str]] = None,
         registry: Optional[KernelRegistry] = None) -> int:
    args = build_parser().parse_args(argv)
    profiles = None
    if args.profile:
        try:
            profiles = [get_profile(p) for p in args.profile]
        except KeyError as e:
            print(f"error: {e.args[0]}", file=sys.stderr)
            return 2
    try:
        report = analyze_registry(registry, kernels=args.kernel,
                                  profiles=profiles,
                                  exact_limit=args.exact_limit,
                                  samples=args.samples)
    except KeyError as e:
        print(f"error: {e.args[0]}", file=sys.stderr)
        return 2
    if not args.quiet:
        print(render_text(report), file=sys.stderr)
    payload = report.dumps()
    if args.json:
        with open(args.json, "w") as f:
            f.write(payload + "\n")
    else:
        print(payload)
    return report.exit_code(strict=args.strict)


if __name__ == "__main__":                            # pragma: no cover
    sys.exit(main())
