"""Static search-space audit: satisfiability, dead values, constraint health.

CLTune's spaces are small cartesian products filtered by lambda
constraints; most declaration bugs are therefore *statically decidable*
by bounded enumeration: a constraint set with an empty feasible set, a
parameter value that no feasible config ever takes (dead weight the
strategies keep resampling), a constraint referencing a parameter that
was never declared, or a constraint that rejects nothing the others
don't already reject.

Paper-scale extended spaces (GEMM: ~250k raw points) are too large to
enumerate in a pre-search pass, so the audit falls back to *stratified*
sampling — every (parameter, value) pair is guaranteed to appear in the
sample, so a value reported dead was really rejected in combination
with a balanced mix of the other parameters — and the report carries an
explicit ``confidence`` verdict: ``exact`` (enumerated, claims are
proofs) or ``probabilistic`` (sampled, claims are evidence).
"""

from __future__ import annotations

import dataclasses
import math
import random
from typing import Any, Dict, List, Mapping, Optional, Tuple

from ..core.space import Config, Constraint, SearchSpace, _value_ident
from .findings import Finding

#: Spaces at or below this raw cardinality are enumerated exactly.
DEFAULT_EXACT_LIMIT = 20_000
#: Stratified sample size for spaces above the exact limit.
DEFAULT_SAMPLES = 2_048


@dataclasses.dataclass
class SpaceReport:
    """Outcome of one :func:`audit_space` pass over a `SearchSpace`."""

    #: raw cartesian-product size (unconstrained)
    cardinality: int
    #: configs actually evaluated against the constraints
    examined: int
    #: feasible configs among the examined ones
    feasible: int
    #: feasible fraction of the examined set
    feasible_fraction: float
    #: ``exact`` (bounded enumeration) or ``probabilistic`` (stratified)
    confidence: str
    #: param name -> values appearing in no examined feasible config
    dead_values: Dict[str, List[Any]]
    #: labels of constraints that rejected no config at all (exact only)
    vacuous_constraints: List[str]
    #: labels of constraints whose every rejection was co-rejected by
    #: another constraint — implied/redundant (exact only)
    implied_constraints: List[str]
    #: constraint label -> parameter names it references that the space
    #: does not declare
    unknown_params: Dict[str, List[str]]
    #: constraint label -> count of configs whose check raised
    constraint_errors: Dict[str, int]
    #: no examined config satisfied every constraint
    unsatisfiable: bool
    #: a bounded sample of feasible configs (for downstream resource checks)
    feasible_sample: List[Config]

    def stats(self) -> Dict[str, Any]:
        """Compact mapping for tuner reports / bench records."""
        return {
            "cardinality": self.cardinality,
            "examined": self.examined,
            "feasible": self.feasible,
            "feasible_fraction": round(self.feasible_fraction, 4),
            "confidence": self.confidence,
            "dead_values": sum(len(v) for v in self.dead_values.values()),
            "vacuous_constraints": len(self.vacuous_constraints),
            "implied_constraints": len(self.implied_constraints),
        }


def _constraint_label(c: Constraint, index: int) -> str:
    base = c.label or f"constraint over {list(c.names)}"
    return f"#{index}:{base}"


def _check_safe(c: Constraint, cfg: Mapping[str, object]) -> Optional[bool]:
    """Evaluate a constraint; ``None`` means the predicate itself raised."""
    try:
        return bool(c.check(cfg))
    except Exception:
        return None


def _stratified_sample(space: SearchSpace, samples: int,
                       rng: random.Random) -> List[Config]:
    """Balanced sample: each (param, value) appears ~samples/len(values)
    times; per-parameter columns are shuffled independently, then zipped.

    This is the latin-hypercube idea on discrete axes: unlike i.i.d.
    uniform draws it cannot miss a value entirely, which is what makes a
    sampled dead-value claim meaningful.
    """
    columns: List[List[object]] = []
    for p in space.parameters:
        reps = math.ceil(samples / len(p.values))
        col = list(p.values) * reps
        rng.shuffle(col)
        columns.append(col[:samples])
    names = space.names
    return [dict(zip(names, row)) for row in zip(*columns)]


def audit_space(space: SearchSpace, *,
                exact_limit: int = DEFAULT_EXACT_LIMIT,
                samples: int = DEFAULT_SAMPLES,
                sample_cap: int = 512,
                seed: int = 0) -> SpaceReport:
    """Audit a space: exact below ``exact_limit``, stratified above it."""
    params = space.parameters
    constraints = space.constraints
    declared = set(space.names)

    labels = [_constraint_label(c, i) for i, c in enumerate(constraints)]
    unknown: Dict[str, List[str]] = {}
    evaluable: List[Tuple[int, Constraint]] = []
    for i, c in enumerate(constraints):
        missing = [n for n in c.names if n not in declared]
        if missing:
            unknown[labels[i]] = missing
        else:
            evaluable.append((i, c))

    cardinality = space.cardinality()
    exact = cardinality <= max(1, exact_limit)
    if exact:
        candidates = _enumerate_product(space)
        examined = cardinality
    else:
        rng = random.Random(seed)
        samples = max(samples, max((len(p.values) for p in params),
                                   default=1))
        candidates = _stratified_sample(space, samples, rng)
        examined = len(candidates)

    alive: Dict[str, set] = {p.name: set() for p in params}
    reject = [0] * len(constraints)
    sole = [0] * len(constraints)
    errors = [0] * len(constraints)
    feasible = 0
    feasible_sample: List[Config] = []

    for cfg in candidates:
        violated: List[int] = []
        for i, c in evaluable:
            ok = _check_safe(c, cfg)
            if ok is None:
                errors[i] += 1
                violated.append(i)
            elif not ok:
                violated.append(i)
        if not violated:
            feasible += 1
            if len(feasible_sample) < sample_cap:
                feasible_sample.append(dict(cfg))
            for name, value in cfg.items():
                alive[name].add(_value_ident(value))
        else:
            for i in violated:
                reject[i] += 1
            if len(violated) == 1:
                sole[violated[0]] += 1

    dead_values: Dict[str, List[Any]] = {}
    for p in params:
        dead = [v for v in p.values if _value_ident(v) not in alive[p.name]]
        if dead:
            dead_values[p.name] = dead

    vacuous: List[str] = []
    implied: List[str] = []
    if exact:
        for i, _ in evaluable:
            if errors[i]:
                continue
            if reject[i] == 0:
                vacuous.append(labels[i])
            elif sole[i] == 0:
                implied.append(labels[i])

    constraint_errors = {labels[i]: n for i, n in enumerate(errors) if n}

    return SpaceReport(
        cardinality=cardinality,
        examined=examined,
        feasible=feasible,
        feasible_fraction=feasible / examined if examined else 0.0,
        confidence="exact" if exact else "probabilistic",
        dead_values=dead_values,
        vacuous_constraints=vacuous,
        implied_constraints=implied,
        unknown_params=unknown,
        constraint_errors=constraint_errors,
        unsatisfiable=(feasible == 0),
        feasible_sample=feasible_sample,
    )


def _enumerate_product(space: SearchSpace) -> List[Config]:
    import itertools
    names = space.names
    return [dict(zip(names, combo))
            for combo in itertools.product(
                *(p.values for p in space.parameters))]


def space_findings(report: SpaceReport, *, kernel: str = "",
                   shape: Optional[Mapping[str, Any]] = None,
                   space_name: str = "default") -> List[Finding]:
    """Map a :class:`SpaceReport` onto typed findings.

    Severity policy: anything *proved* broken (exact confidence) is an
    error; the same observation under sampling is a warning (still
    strong evidence — stratification covered every value); statistics
    and redundancy observations are info.
    """
    shape_d = dict(shape) if shape is not None else None
    exact = report.confidence == "exact"
    out: List[Finding] = []

    def finding(rule_id: str, severity: str, detail: str,
                **data: Any) -> Finding:
        data.setdefault("space", space_name)
        data.setdefault("confidence", report.confidence)
        return Finding(rule_id=rule_id, severity=severity, kernel=kernel,
                       detail=f"[{space_name} space] {detail}",
                       shape=shape_d, data=data)

    for label, missing in report.unknown_params.items():
        out.append(finding(
            "space-unknown-param", "error",
            f"constraint {label} references undeclared parameter(s) "
            f"{missing}", constraint=label, missing=missing))

    for label, n in report.constraint_errors.items():
        out.append(finding(
            "space-constraint-raises", "error",
            f"constraint {label} raised on {n}/{report.examined} "
            f"examined config(s); a raising predicate kills searches "
            f"mid-strategy", constraint=label, raised=n))

    if report.unsatisfiable:
        if exact:
            detail = (f"no feasible config exists: all "
                      f"{report.examined} configs violate the "
                      f"constraint set")
        else:
            detail = (f"probably unsatisfiable: 0 of {report.examined} "
                      f"stratified samples feasible "
                      f"(cardinality {report.cardinality})")
        out.append(finding("space-unsatisfiable",
                           "error" if exact else "warning", detail,
                           examined=report.examined))
        return out          # everything below is noise once the set is empty

    for name, dead in report.dead_values.items():
        if exact:
            detail = (f"parameter {name!r}: value(s) {dead} appear in no "
                      f"feasible config (dead weight for every strategy)")
        else:
            detail = (f"parameter {name!r}: value(s) {dead} appeared in no "
                      f"feasible config across {report.examined} stratified "
                      f"samples (probabilistic)")
        out.append(finding("space-dead-value",
                           "warning" if exact else "info", detail,
                           param=name, values=dead))

    for label in report.vacuous_constraints:
        out.append(finding(
            "space-vacuous-constraint", "info",
            f"constraint {label} rejected no config — it can be removed",
            constraint=label))
    for label in report.implied_constraints:
        out.append(finding(
            "space-implied-constraint", "info",
            f"constraint {label} is implied: every config it rejects is "
            f"also rejected by another constraint", constraint=label))

    return out
