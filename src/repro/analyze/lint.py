"""Declaration linter: registry-wide static checks over `@tunable` kernels.

Each rule produces typed :class:`~repro.analyze.findings.Finding` rows;
:func:`analyze_registry` sweeps every registered tunable at its declared
default shapes across the built-in device profiles.  Shape-free kernels
(no ``default_shapes``, e.g. the sharding cell) still get the
declaration-level rules; space/resource rules need a concrete shape.

Rule inventory (see README "Static analysis" for the table):

==========================  ========  =====================================
rule_id                     severity  meaning
==========================  ========  =====================================
space-unsatisfiable         error*    constraint set admits no config
space-unknown-param         error     constraint references undeclared name
space-constraint-raises     error     constraint predicate raises
space-dead-value            warning*  value appears in no feasible config
space-vacuous-constraint    info      constraint rejects nothing
space-implied-constraint    info      constraint implied by the others
space-build-error           error     space()/make_space raised
space-over-vmem             error*    every feasible config over VMEM budget
footprint-model-raises      error     vmem_footprint raises on feasible cfgs
device-feasibility          info      proven-infeasible fraction per device
align-sublane/align-mxu     info      heuristic tile misaligned (padding)
heuristic-raises            error     heuristic(shape) raises
heuristic-out-of-space      warning   heuristic names/values outside space
heuristic-infeasible        warning   heuristic violates constraints
heuristic-over-vmem         warning   heuristic config over a device budget
extended-not-superset       error     extended space loses default values
constraint-arity            error     constraint fn arity != len(names)
bool-int-aliasing           warning   param mixes bool and equal int values
missing-analytical-model    warning   no model but cost-model paths declared
no-default-shapes           info      kernel skipped space/resource rules
==========================  ========  =====================================

(* probabilistic confidence demotes the severity one step.)
"""

from __future__ import annotations

from typing import Any, Dict, Iterable, List, Mapping, Optional, Sequence

from ..core.profiles import PROFILES, DeviceProfile
from ..core.registry import REGISTRY, KernelRegistry, TunableKernel
from ..core.space import (Constraint, SearchSpace, _value_ident,
                          constraint_arity_error)
from .findings import AnalysisReport, Finding
from .resource import (alignment_findings, proven_violations,
                       resource_findings)
from .space_audit import (DEFAULT_EXACT_LIMIT, DEFAULT_SAMPLES, audit_space,
                          space_findings)

Shape = Mapping[str, Any]


# constraint-arity checking: SearchSpace.add_constraint raises on new
# declarations; this rule catches pre-built / hand-assembled spaces
def _arity_findings(space: SearchSpace, kernel: str,
                    shape: Optional[Shape], space_name: str) -> List[Finding]:
    out = []
    for i, c in enumerate(space.constraints):
        label = c.label or f"constraint over {list(c.names)}"
        err = constraint_arity_error(c.fn, len(c.names))
        if err:
            out.append(Finding(
                rule_id="constraint-arity", severity="error", kernel=kernel,
                shape=dict(shape) if shape else None,
                detail=f"[{space_name} space] #{i}:{label}: {err}",
                data={"constraint": label, "space": space_name}))
    return out


def _alias_findings(space: SearchSpace, kernel: str,
                    shape: Optional[Shape], space_name: str) -> List[Finding]:
    """Params mixing bools with the ints they compare equal to.

    ``(0, 1, True)`` is legal (the space machinery is bool-aware since
    PR 5) but almost always a declaration typo: caches, JSON round-trips
    and user code conflate the aliased pair.
    """
    out = []
    for p in space.parameters:
        bools = {v for v in p.values if isinstance(v, bool)}
        if not bools:
            continue
        aliased = [v for v in p.values
                   if not isinstance(v, bool)
                   and any(v == b for b in bools)]
        if aliased:
            out.append(Finding(
                rule_id="bool-int-aliasing", severity="warning",
                kernel=kernel, shape=dict(shape) if shape else None,
                detail=f"[{space_name} space] parameter {p.name!r} mixes "
                       f"bool values {sorted(bools)} with equal int "
                       f"value(s) {aliased}; JSON/cache round-trips "
                       f"conflate them",
                data={"param": p.name, "space": space_name}))
    return out


def _heuristic_findings(k: TunableKernel, shape: Shape,
                        space: SearchSpace,
                        profiles: Sequence[DeviceProfile]) -> List[Finding]:
    out: List[Finding] = []
    try:
        h = dict(k.heuristic(dict(shape)))
    except Exception as e:
        return [Finding(
            rule_id="heuristic-raises", severity="error", kernel=k.name,
            shape=dict(shape),
            detail=f"heuristic raised {type(e).__name__}: {e}")]

    by_name = {p.name: p for p in space.parameters}
    extra = sorted(set(h) - set(by_name))
    off_value = {}
    for name, value in h.items():
        p = by_name.get(name)
        if p is None:
            continue
        try:
            p.index_of(value)
        except ValueError:
            off_value[name] = value
    if extra or off_value:
        out.append(Finding(
            rule_id="heuristic-out-of-space", severity="warning",
            kernel=k.name, shape=dict(shape),
            detail=f"heuristic strays from the default space: "
                   f"extra names {extra or '[]'}, out-of-list values "
                   f"{off_value or '{}'} (runtime projects these, but the "
                   f"declared intent is lost)",
            data={"extra": extra, "off_value": off_value}))

    def _violates(c: Constraint, config: Dict[str, object]) -> bool:
        # a raising constraint is the audit's space-constraint-raises
        # finding, not a heuristic-infeasibility verdict
        try:
            return not c.check(config)
        except Exception:
            return False

    known = {n: v for n, v in h.items() if n in by_name}
    if not off_value and set(known) == set(by_name):
        labels = [c.label or repr(c.names) for c in space.constraints
                  if set(c.names) <= set(known) and _violates(c, known)]
        if labels:
            out.append(Finding(
                rule_id="heuristic-infeasible", severity="warning",
                kernel=k.name, shape=dict(shape),
                detail=f"heuristic violates constraint(s) {labels} "
                       f"(runtime projects it to a feasible neighbour)",
                data={"violated": labels}))
        else:
            # feasible heuristic: device-budget + alignment advisories
            for prof in profiles:
                viol = proven_violations(k, shape, h, prof)
                if viol:
                    out.append(Finding(
                        rule_id="heuristic-over-vmem", severity="warning",
                        kernel=k.name, shape=dict(shape), profile=prof.name,
                        detail=f"heuristic config is proven infeasible on "
                               f"{prof.name}: {'; '.join(viol)}",
                        data={"violations": viol}))
            if profiles:
                out.extend(alignment_findings(k, shape, h, profiles[0],
                                              context="heuristic"))
    return out


def _extended_superset_findings(k: TunableKernel, shape: Shape,
                                default_space: SearchSpace) -> List[Finding]:
    if not k.supports_extended():
        return []
    try:
        ext = k.make_space(dict(shape), extended=True)
    except Exception as e:
        return [Finding(
            rule_id="space-build-error", severity="error", kernel=k.name,
            shape=dict(shape),
            detail=f"extended space build raised {type(e).__name__}: {e}",
            data={"space": "extended"})]
    ext_by_name = {p.name: p for p in ext.parameters}
    out = []
    for p in default_space.parameters:
        q = ext_by_name.get(p.name)
        if q is None:
            out.append(Finding(
                rule_id="extended-not-superset", severity="error",
                kernel=k.name, shape=dict(shape),
                detail=f"extended space drops parameter {p.name!r} — tuned "
                       f"extended configs cannot serve default-space calls",
                data={"param": p.name}))
            continue
        ext_idents = {_value_ident(v) for v in q.values}
        lost = [v for v in p.values if _value_ident(v) not in ext_idents]
        if lost:
            out.append(Finding(
                rule_id="extended-not-superset", severity="error",
                kernel=k.name, shape=dict(shape),
                detail=f"extended space loses default value(s) {lost} of "
                       f"parameter {p.name!r}",
                data={"param": p.name, "lost": lost}))
    return out


def _declaration_findings(k: TunableKernel) -> List[Finding]:
    out: List[Finding] = []
    if k.analytical_model is None:
        defaults = {str(v).lower() for v in k.defaults.values()}
        needs = bool({"costmodel", "analytical"} & defaults)
        out.append(Finding(
            rule_id="missing-analytical-model",
            severity="error" if needs else "warning",
            kernel=k.name,
            detail="no analytical_model declared"
                   + (": the kernel's own defaults request a cost-model "
                      "path that cannot be built" if needs else
                      "; CostModelPredictor / analytical evaluation are "
                      "unavailable for this kernel"),
            data={"required_by_defaults": needs}))
    return out


def kernel_findings(k: TunableKernel, *,
                    shapes: Optional[Iterable[Shape]] = None,
                    profiles: Optional[Sequence[DeviceProfile]] = None,
                    exact_limit: int = DEFAULT_EXACT_LIMIT,
                    samples: int = DEFAULT_SAMPLES,
                    seed: int = 0) -> List[Finding]:
    """All findings for one tunable kernel."""
    shape_list = [dict(s) for s in (shapes if shapes is not None
                                    else k.default_shapes)]
    prof_list = list(profiles if profiles is not None
                     else PROFILES.values())
    findings: List[Finding] = list(_declaration_findings(k))

    if not shape_list:
        findings.append(Finding(
            rule_id="no-default-shapes", severity="info", kernel=k.name,
            detail="kernel declares no default_shapes; space and resource "
                   "rules skipped (pass explicit shapes to audit them)"))
        return findings

    for shape in shape_list:
        try:
            space = k.make_space(dict(shape))
        except Exception as e:
            findings.append(Finding(
                rule_id="space-build-error", severity="error", kernel=k.name,
                shape=dict(shape),
                detail=f"space build raised {type(e).__name__}: {e}",
                data={"space": "default"}))
            continue

        report = audit_space(space, exact_limit=exact_limit,
                             samples=samples, seed=seed)
        findings.extend(space_findings(report, kernel=k.name, shape=shape))
        findings.extend(_arity_findings(space, k.name, shape, "default"))
        findings.extend(_alias_findings(space, k.name, shape, "default"))
        findings.extend(_heuristic_findings(k, shape, space, prof_list))
        findings.extend(_extended_superset_findings(k, shape, space))
        if not report.unsatisfiable:
            for prof in prof_list:
                findings.extend(resource_findings(
                    k, shape, prof, report.feasible_sample,
                    report.confidence))
    return findings


def analyze_registry(registry: Optional[KernelRegistry] = None, *,
                     kernels: Optional[Sequence[str]] = None,
                     profiles: Optional[Sequence[DeviceProfile]] = None,
                     exact_limit: int = DEFAULT_EXACT_LIMIT,
                     samples: int = DEFAULT_SAMPLES,
                     seed: int = 0) -> AnalysisReport:
    """Sweep every registered tunable (or the named subset)."""
    if registry is None:
        from ..core.registry import _ensure_builtins
        _ensure_builtins()                      # load the built-in tunables
        registry = REGISTRY
    names = list(kernels) if kernels else sorted(registry.names())
    report = AnalysisReport()
    for name in names:
        report.extend(kernel_findings(registry.get(name),
                                      profiles=profiles,
                                      exact_limit=exact_limit,
                                      samples=samples, seed=seed))
    return report


# re-exported convenience: grouped human rendering for the CLI
def render_text(report: AnalysisReport) -> str:
    by_kernel: Dict[str, List[Finding]] = {}
    for f in report:
        by_kernel.setdefault(f.kernel or "<unattributed>", []).append(f)
    lines: List[str] = []
    for kernel in sorted(by_kernel):
        lines.append(f"{kernel}:")
        lines.extend(f"  {f}" for f in by_kernel[kernel])
    lines.append(report.summary())
    return "\n".join(lines)
