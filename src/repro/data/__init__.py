from .pipeline import EOS, DataConfig, Prefetcher, TokenSource

__all__ = ["EOS", "DataConfig", "Prefetcher", "TokenSource"]
