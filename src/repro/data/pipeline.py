"""Deterministic, resumable, sharded token pipeline.

Production constraints honoured:
  * deterministic as a function of (seed, step) — a restore at step k
    replays exactly the batch stream from step k (bitwise resume);
  * per-host sharding — each host generates only its slice of the global
    batch (no host materialises the global array at scale);
  * background prefetch with bounded queue (overlaps host data work with
    device steps);
  * document-pack synthetic corpus by default (zipf token distribution,
    EOS-delimited docs) or memory-mapped token files.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np

EOS = 0


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    vocab_size: int
    seed: int = 0
    #: synthetic | file
    source: str = "synthetic"
    path: Optional[str] = None
    #: this host's slice (host_index, host_count)
    host_index: int = 0
    host_count: int = 1
    #: zipf exponent for the synthetic corpus
    zipf_a: float = 1.3
    mean_doc_len: int = 512

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.host_count == 0
        return self.global_batch // self.host_count


class TokenSource:
    """Step-indexed batch generator: batch(step) is a pure function."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self._file_tokens: Optional[np.ndarray] = None
        if cfg.source == "file":
            if not cfg.path:
                raise ValueError("file source requires path")
            self._file_tokens = np.memmap(cfg.path, dtype=np.int32,
                                          mode="r")

    def _rng(self, step: int, row: int) -> np.random.Generator:
        c = self.cfg
        seed = (np.uint64(c.seed) * np.uint64(0x9E3779B97F4A7C15)
                + np.uint64(step) * np.uint64(0xBF58476D1CE4E5B9)
                + np.uint64(c.host_index * c.host_batch + row))
        return np.random.default_rng(np.uint64(seed))

    def _synthetic_row(self, step: int, row: int) -> np.ndarray:
        c = self.cfg
        rng = self._rng(step, row)
        out = np.empty(c.seq_len + 1, np.int32)
        i = 0
        while i < c.seq_len + 1:
            dlen = int(rng.exponential(c.mean_doc_len)) + 8
            doc = rng.zipf(c.zipf_a, size=dlen).astype(np.int64)
            doc = (doc % (c.vocab_size - 1)) + 1          # reserve EOS=0
            n = min(dlen, c.seq_len + 1 - i)
            out[i:i + n] = doc[:n]
            i += n
            if i < c.seq_len + 1:
                out[i] = EOS
                i += 1
        return out

    def _file_row(self, step: int, row: int) -> np.ndarray:
        c = self.cfg
        total = self._file_tokens.shape[0] - (c.seq_len + 1)
        rng = self._rng(step, row)
        start = int(rng.integers(0, total))
        return np.asarray(self._file_tokens[start:start + c.seq_len + 1],
                          np.int32)

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        """Host-local slice of the global batch for ``step``."""
        c = self.cfg
        make = self._file_row if c.source == "file" else self._synthetic_row
        rows = np.stack([make(step, r) for r in range(c.host_batch)])
        return {"tokens": rows[:, :-1],
                "labels": rows[:, 1:].astype(np.int32)}


class Prefetcher:
    """Bounded background prefetch of step-indexed batches."""

    def __init__(self, source: TokenSource, start_step: int = 0,
                 depth: int = 2):
        self._source = source
        self._queue: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        step = self._step
        while not self._stop.is_set():
            batch = self._source.batch(step)
            while not self._stop.is_set():
                try:
                    self._queue.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        while True:
            try:
                return self._queue.get(timeout=1.0)
            except queue.Empty:
                if self._stop.is_set():
                    raise StopIteration
                continue

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2.0)
