"""Production mesh construction (brief-mandated shapes).

A FUNCTION, not a module-level constant: importing this module never touches
jax device state, so tests that want 1 CPU device can import it safely.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """Small mesh over whatever devices exist (unit tests, examples)."""
    n = len(jax.devices())
    model_axis = max(1, min(model_axis, n))
    data_axis = n // model_axis
    return jax.make_mesh((data_axis, model_axis), ("data", "model"))


def mesh_chips(mesh) -> int:
    return mesh.devices.size
