"""Training launcher: ``python -m repro.launch.train --arch granite-3-2b``.

Runs a real (CPU-feasible) training job on the smoke config by default, or
the full config when ``--full`` is given (requires the matching hardware).
Wires the complete production path: deterministic sharded data, sharded
train step, checkpoints, straggler monitor, resume.
"""

from __future__ import annotations

import argparse
import logging


from repro.configs import get_config
from repro.data import DataConfig
from repro.models.model import RunConfig
from repro.optim import adamw
from repro.train import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--full", action="store_true",
                    help="use the full published config (needs real TPUs)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--remat", default="none",
                    choices=["none", "full", "dots"])
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")
    cfg = get_config(args.arch, smoke=not args.full)
    data_cfg = DataConfig(seq_len=args.seq_len,
                          global_batch=args.global_batch,
                          vocab_size=cfg.vocab_size)
    trainer = Trainer(
        cfg, data_cfg,
        TrainerConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                      ckpt_dir=args.ckpt_dir, log_every=args.log_every),
        run=RunConfig(remat=args.remat, microbatch=args.microbatch),
        opt_cfg=adamw.OptimConfig(lr=args.lr, total_steps=args.steps))
    if not args.resume:
        trainer.init_state()
    out = trainer.train()
    first = out["history"][0]["loss"] if out["history"] else float("nan")
    last = out["history"][-1]["loss"] if out["history"] else float("nan")
    print(f"trained {args.arch} ({cfg.name}) to step {out['final_step']}: "
          f"loss {first:.4f} -> {last:.4f}")


if __name__ == "__main__":
    main()
