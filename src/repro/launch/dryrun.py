import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ---------------------------------------------------------------------------
# Multi-pod dry-run: lower + compile every (architecture x input shape) on
# the production meshes, extract memory/cost/collective analyses, and emit
# the roofline terms (EXPERIMENTS.md section Dry-run / section Roofline).
#
# The two lines above MUST run before any other import (jax locks the device
# count at backend initialisation).
# ---------------------------------------------------------------------------

import argparse        # noqa: E402
import dataclasses     # noqa: E402
import json            # noqa: E402
import time            # noqa: E402
import traceback       # noqa: E402
from typing import Any, Dict, Optional  # noqa: E402

import jax             # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import get_arch, input_specs  # noqa: E402
from repro.core.hlo import collective_stats, fusion_stats  # noqa: E402
from repro.core.profiles import TPU_V5E  # noqa: E402
from repro.dist import partition, sharding  # noqa: E402
from repro.dist.step import (make_prefill_step, make_serve_step,  # noqa: E402
                             make_train_step)
from repro.launch.mesh import make_production_mesh, mesh_chips  # noqa: E402
from repro.models import abstract_cache, abstract_model  # noqa: E402
from repro.models.config import SHAPES  # noqa: E402
from repro.models.model import RunConfig  # noqa: E402
from repro.optim import adamw  # noqa: E402

REPLICATED = None   # shorthand

# gradient-sharding constraints (EXPERIMENTS.md §Perf B6/C6): opt-in via
# env var so the recorded baseline sweep stays reproducible.
SHARD_GRADS_DEFAULT = os.environ.get("REPRO_SHARD_GRADS", "0") == "1"


# per-arch attention sharding mode (DESIGN.md §6): 'expanded' when KV < 16
# but H divides the model axis; 'grouped' + sequence-parallel rule when H
# does not divide (qwen 40, llava 56, musicgen 24).
ARCH_ATTN_MODE = {
    "mistral-large-123b": "expanded",   # H=96, KV=8
    "qwen2.5-32b": "grouped",           # H=40 indivisible -> seq-parallel
    "granite-34b": "expanded",          # H=48, KV=1
    "granite-3-2b": "expanded",         # H=32, KV=8
    "deepseek-v3-671b": "grouped",      # MLA, H=128 divisible
    "kimi-k2-1t-a32b": "expanded",      # H=64, KV=8
    "llava-next-34b": "grouped",        # H=56 indivisible -> seq-parallel
    "zamba2-7b": "grouped",             # KV=32 divisible
    "musicgen-medium": "grouped",       # H=24 indivisible -> seq-parallel
    "mamba2-130m": "grouped",           # attention-free
}

SEQ_PARALLEL_ARCHS = {"qwen2.5-32b", "llava-next-34b", "musicgen-medium"}

# gradient-accumulation microbatches for training (keeps per-layer residual
# memory bounded); scaled roughly with d_model * layers.
ARCH_TRAIN_MICROBATCH = {
    "mistral-large-123b": 8,
    "qwen2.5-32b": 4,
    "granite-34b": 4,
    "granite-3-2b": 1,
    "deepseek-v3-671b": 8,
    "kimi-k2-1t-a32b": 8,
    "llava-next-34b": 4,
    "zamba2-7b": 2,
    "musicgen-medium": 1,
    "mamba2-130m": 1,
}


def default_rules_override(arch_id: str) -> Dict[str, Any]:
    if arch_id in SEQ_PARALLEL_ARCHS:
        return {"seq_attn": "model"}
    return {}


def default_run_config(arch_id: str, shape_name: str) -> RunConfig:
    """Baseline execution knobs per cell (the hillclimb's starting point)."""
    shape = SHAPES[shape_name]
    remat = "full" if shape.kind == "train" else "none"
    attn_chunk = 2048 if (shape.kind != "decode"
                          and shape.seq_len >= 32_768) else 0
    ce_chunk = 512 if shape.kind == "train" else 0
    micro = ARCH_TRAIN_MICROBATCH.get(arch_id, 1) \
        if shape.kind == "train" else 1
    accum = "bfloat16" if arch_id in ("deepseek-v3-671b",
                                      "kimi-k2-1t-a32b") else "float32"
    return RunConfig(remat=remat, attn_chunk=attn_chunk, ce_chunk=ce_chunk,
                     attn_mode=ARCH_ATTN_MODE.get(arch_id, "grouped"),
                     microbatch=micro, accum_dtype=accum)


def default_opt_config(arch_id: str) -> adamw.OptimConfig:
    # giant MoEs: bf16 moments (compressed optimizer) so params+opt approach
    # pod HBM; everything else keeps f32 moments.
    if arch_id in ("deepseek-v3-671b", "kimi-k2-1t-a32b"):
        return adamw.OptimConfig(moment_dtype="bfloat16")
    return adamw.OptimConfig()


def _mem_analysis(compiled) -> Dict[str, float]:
    out: Dict[str, float] = {}
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return out
    for field in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes",
                  "serialized_size_in_bytes"):
        v = getattr(ma, field, None)
        if v is not None:
            out[field] = float(v)
    if out:
        out["total_bytes_per_device"] = (
            out.get("argument_size_in_bytes", 0.0)
            + out.get("output_size_in_bytes", 0.0)
            + out.get("temp_size_in_bytes", 0.0)
            - out.get("alias_size_in_bytes", 0.0))
    return out


def model_flops(cfg, shape, kind: str) -> float:
    """6*N*D (train) / 2*N*D (inference) with N = active params."""
    n_active = cfg.num_active_params()
    if kind == "train":
        return 6.0 * n_active * shape.tokens
    if kind == "prefill":
        return 2.0 * n_active * shape.tokens
    return 2.0 * n_active * shape.global_batch      # one decode step


# ---------------------------------------------------------------------------
# cost measurement.  XLA's cost_analysis (and the HLO text) count a while-
# loop body ONCE, so a scanned layer stack under-reports flops/bytes/
# collectives by ~L.  We therefore measure reduced-depth UNROLLED variants
# (depths L1 < L2) and extrapolate linearly: cost(L) = c1 + (L - L1) * per
# with per = (c2 - c1) / (L2 - L1).  The production (scanned) artifact is
# still compiled for memory analysis and compile-time stats.
# ---------------------------------------------------------------------------

def _build_lowered(cfg, shape, run: RunConfig, mesh, rules,
                   opt_cfg: adamw.OptimConfig, shard_grads: bool = None):
    """Lower one step function for (cfg, shape) under mesh+rules."""
    if shard_grads is None:
        shard_grads = SHARD_GRADS_DEFAULT
    with sharding.use_sharding(mesh, rules):
        params = abstract_model(cfg)
        p_shard = partition.model_shardings(cfg, mesh, rules)
        b_shard = partition.batch_shardings(cfg, shape, mesh, rules)
        batch = input_specs(cfg, shape)
        if shape.kind == "train":
            opt = adamw.abstract_state(opt_cfg, params)
            o_shard = partition.opt_shardings(p_shard, mesh)
            fn = make_train_step(
                cfg, run, opt_cfg,
                grad_shardings=p_shard if shard_grads else None)
            jitted = jax.jit(fn, in_shardings=(p_shard, o_shard, b_shard),
                             out_shardings=(p_shard, o_shard, REPLICATED),
                             donate_argnums=(0, 1))
            return jitted.lower(params, opt, batch)
        if shape.kind == "prefill":
            fn = make_prefill_step(cfg, run)
            jitted = jax.jit(fn, in_shardings=(p_shard, b_shard))
            return jitted.lower(params, batch)
        cache = abstract_cache(cfg, shape.global_batch, shape.seq_len)
        c_shard = partition.cache_shardings(
            cfg, shape.global_batch, shape.seq_len, mesh, rules)
        fn = make_serve_step(cfg, run)
        jitted = jax.jit(fn,
                         in_shardings=(p_shard, c_shard, b_shard["inputs"],
                                       REPLICATED),
                         out_shardings=(REPLICATED, c_shard),
                         donate_argnums=(1,))
        pos = jax.ShapeDtypeStruct((), jnp.int32)
        return jitted.lower(params, cache, batch["inputs"], pos)


def _module_costs(compiled) -> Dict[str, Any]:
    cost = compiled.cost_analysis() or {}
    coll = collective_stats(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll_weighted": coll.weighted_bytes,
        "coll_total": float(coll.total_bytes),
        "coll_by_op": dict(coll.bytes_by_op),
        "coll_counts": dict(coll.counts),
    }


def _measurement_depths(cfg) -> tuple:
    """(L1, L2, extrapolation-count) reduced depths for cost measurement."""
    if cfg.family == "hybrid":
        unit = cfg.hybrid_mamba_per_attn + 1
        n_super = cfg.num_layers // unit
        return unit, 2 * unit, None     # per-super-block delta
    if cfg.is_moe:
        d = cfg.moe_first_dense
        return d + 1, d + 2, None
    return 1, 2, None


def _extrapolate(c1: Dict[str, Any], c2: Dict[str, Any],
                 n_units: float) -> Dict[str, Any]:
    """cost = c1 + (n_units - 1) * (c2 - c1), element-wise."""
    out: Dict[str, Any] = {}
    for k in ("flops", "bytes", "coll_weighted", "coll_total"):
        out[k] = c1[k] + (n_units - 1) * max(0.0, c2[k] - c1[k])
    out["coll_by_op"] = {
        op: c1["coll_by_op"][op] + (n_units - 1)
        * max(0.0, c2["coll_by_op"][op] - c1["coll_by_op"][op])
        for op in c1["coll_by_op"]}
    out["coll_counts"] = {
        op: int(c1["coll_counts"][op] + (n_units - 1)
                * max(0, c2["coll_counts"][op] - c1["coll_counts"][op]))
        for op in c1["coll_counts"]}
    return out


def measure_costs(cfg, shape, run: RunConfig, mesh, rules,
                  opt_cfg: adamw.OptimConfig) -> Dict[str, Any]:
    """Per-chip flops/bytes/collective costs, scan-corrected."""
    run_m = dataclasses.replace(run, scan_blocks=False, ce_chunk=0,
                                attn_chunk=0, microbatch=1)
    L1, L2, _ = _measurement_depths(cfg)
    cfg1 = dataclasses.replace(cfg, num_layers=L1)
    cfg2 = dataclasses.replace(cfg, num_layers=L2)
    c1 = _module_costs(_build_lowered(cfg1, shape, run_m, mesh, rules,
                                      opt_cfg).compile())
    c2 = _module_costs(_build_lowered(cfg2, shape, run_m, mesh, rules,
                                      opt_cfg).compile())
    if cfg.family == "hybrid":
        unit = cfg.hybrid_mamba_per_attn + 1
        n_units = cfg.num_layers / unit     # tail mambas ~ fractional unit
    elif cfg.is_moe:
        n_units = cfg.num_layers - cfg.moe_first_dense
    else:
        n_units = cfg.num_layers
    out = _extrapolate(c1, c2, n_units)
    out["measured_depths"] = [L1, L2]
    out["n_units"] = n_units
    return out


def analyze_cell(arch_id: str, shape_name: str, *, multi_pod: bool = False,
                 run: Optional[RunConfig] = None,
                 rules_override: Optional[Dict[str, Any]] = None,
                 opt_cfg: Optional[adamw.OptimConfig] = None,
                 profile=TPU_V5E, keep_text: bool = False) -> Dict[str, Any]:
    """Lower + compile one cell; return the dry-run/roofline record."""
    spec = get_arch(arch_id)
    cfg = spec.full
    shape = SHAPES[shape_name]
    run = run or default_run_config(arch_id, shape_name)
    opt_cfg = opt_cfg or default_opt_config(arch_id)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh_chips(mesh)
    if rules_override is None:
        rules_override = default_rules_override(arch_id)
    record: Dict[str, Any] = {
        "arch": arch_id, "shape": shape_name, "kind": shape.kind,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "chips": chips, "multi_pod": multi_pod,
        "run_config": dataclasses.asdict(run),
        "rules_override": rules_override or {},
    }
    rules = dict(sharding.DEFAULT_RULES, **(rules_override or {}))

    # 1) production artifact: the scanned, deployable program.  Memory
    #    analysis, compile stats and HLO structure come from here.
    t0 = time.perf_counter()
    lowered = _build_lowered(cfg, shape, run, mesh, rules, opt_cfg)
    record["lower_s"] = round(time.perf_counter() - t0, 2)
    t1 = time.perf_counter()
    compiled = lowered.compile()
    record["compile_s"] = round(time.perf_counter() - t1, 2)
    text = compiled.as_text()
    record["hlo_ops"] = fusion_stats(text)
    record["memory"] = _mem_analysis(compiled)
    record["scanned_module_costs"] = _module_costs(compiled)
    if keep_text:
        record["hlo_text"] = text

    # 2) scan-corrected per-chip costs: reduced-depth unrolled variants,
    #    linearly extrapolated (see measure_costs).
    t2 = time.perf_counter()
    costs = measure_costs(cfg, shape, run, mesh, rules, opt_cfg)
    record["measure_s"] = round(time.perf_counter() - t2, 2)

    p = profile
    flops, bytes_ = costs["flops"], costs["bytes"]
    compute_t = flops / p.peak_flops
    memory_t = bytes_ / p.hbm_bw
    coll_t = costs["coll_weighted"] / (p.ici_links * p.ici_bw)
    dominant = max((("compute", compute_t), ("memory", memory_t),
                    ("collective", coll_t)), key=lambda kv: kv[1])[0]
    mf = model_flops(cfg, shape, shape.kind)
    step_t = max(compute_t, memory_t) + coll_t
    record.update({
        "flops_per_chip": flops,
        "bytes_per_chip": bytes_,
        "collective_bytes_per_chip": costs["coll_total"],
        "collective_weighted_bytes": costs["coll_weighted"],
        "collective_by_op": costs["coll_by_op"],
        "collective_counts": costs["coll_counts"],
        "measured_depths": costs["measured_depths"],
        "roofline": {
            "compute_t": compute_t,
            "memory_t": memory_t,
            "collective_t": coll_t,
            "dominant": dominant,
            "step_t": step_t,
            # fraction of the step the chip spends at its compute roofline
            "roofline_fraction": (mf / chips / p.peak_flops) / step_t
            if step_t else 0.0,
        },
        "model_flops_global": mf,
        "model_flops_per_chip": mf / chips,
        "useful_flops_ratio": (mf / chips) / flops if flops else 0.0,
    })
    return record


def run_cells(cells, multi_pod: bool, out_dir: str,
              run_overrides: Optional[Dict[str, Any]] = None,
              rules_override: Optional[Dict[str, Any]] = None,
              keep_going: bool = True):
    os.makedirs(out_dir, exist_ok=True)
    results = []
    for arch_id, shape_name in cells:
        tag = f"{arch_id}__{shape_name}__{'multi' if multi_pod else 'single'}"
        print(f"=== dry-run {tag} ===", flush=True)
        try:
            run = default_run_config(arch_id, shape_name)
            if run_overrides:
                run = dataclasses.replace(run, **run_overrides)
            rec = analyze_cell(arch_id, shape_name, multi_pod=multi_pod,
                               run=run, rules_override=rules_override)
            rec["status"] = "ok"
        except Exception as e:  # noqa: BLE001
            if not keep_going:
                raise
            rec = {"arch": arch_id, "shape": shape_name,
                   "multi_pod": multi_pod, "status": "error",
                   "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-2000:]}
            print(f"    FAILED: {rec['error']}", flush=True)
        path = os.path.join(out_dir, tag + ".json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=2, default=str)
        jax.clear_caches()        # bound compile-cache growth over the sweep
        if rec["status"] == "ok":
            r = rec["roofline"]
            print(f"    lower={rec['lower_s']}s compile={rec['compile_s']}s "
                  f"flops/chip={rec['flops_per_chip']:.3e} "
                  f"dominant={r['dominant']} step={r['step_t']*1e3:.2f}ms "
                  f"mem={rec['memory'].get('total_bytes_per_device', 0)/2**30:.2f}GiB",
                  flush=True)
        results.append(rec)
    return results


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=[None] + list(SHAPES), nargs="?")
    ap.add_argument("--all", action="store_true",
                    help="run every non-skipped (arch x shape) cell")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--remat", default=None,
                    choices=[None, "none", "full", "dots"], nargs="?")
    ap.add_argument("--microbatch", type=int, default=None)
    ap.add_argument("--attn-chunk", type=int, default=None)
    ap.add_argument("--moe-impl", default=None,
                    choices=[None, "scatter", "gather", "onehot"], nargs="?")
    ap.add_argument("--no-scan-blocks", action="store_true",
                    help="unroll the layer stack instead of lax.scan")
    ap.add_argument("--attn-mode", default=None,
                    choices=[None, "grouped", "expanded"], nargs="?")
    ap.add_argument("--accum-dtype", default=None,
                    choices=[None, "float32", "bfloat16"], nargs="?")
    ap.add_argument("--rules", default=None,
                    help="JSON logical->mesh-axis rule overrides")
    ap.add_argument("--fail-fast", action="store_true")
    args = ap.parse_args()

    from repro.configs import all_cells
    if args.all:
        cells = [(a, s) for a, s, _ in all_cells()]
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        cells = [(args.arch, args.shape)]

    overrides = {}
    for k in ("remat", "microbatch", "attn_chunk", "moe_impl", "attn_mode",
              "accum_dtype"):
        v = getattr(args, k.replace("-", "_"))
        if v is not None:
            overrides[k] = v
    if args.no_scan_blocks:
        overrides["scan_blocks"] = False
    rules_override = json.loads(args.rules) if args.rules else None

    meshes = [args.multi_pod] if not args.both_meshes else [False, True]
    for mp in meshes:
        run_cells(cells, mp, args.out, run_overrides=overrides or None,
                  rules_override=rules_override,
                  keep_going=not args.fail_fast)


if __name__ == "__main__":
    main()
