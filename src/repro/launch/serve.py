"""Serving launcher: batched greedy decoding with continuous batching.

``python -m repro.launch.serve --arch granite-3-2b --requests 8``
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models.model import init_model
from repro.serve import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=not args.full)
    params = init_model(cfg, jax.random.PRNGKey(args.seed))
    engine = ServeEngine(cfg, params, slots=args.slots,
                         max_len=args.max_len)
    rng = np.random.default_rng(args.seed)
    for rid in range(args.requests):
        prompt = rng.integers(1, cfg.vocab_size,
                              size=int(rng.integers(4, 12))).tolist()
        engine.submit(Request(rid=rid, prompt=prompt,
                              max_new_tokens=args.max_new_tokens))
    t0 = time.perf_counter()
    done = engine.run()
    dt = time.perf_counter() - t0
    total_tokens = sum(len(r.output) for r in done)
    print(f"served {len(done)} requests, {total_tokens} tokens "
          f"in {dt:.2f}s ({total_tokens / dt:.1f} tok/s)")
    for r in done[:3]:
        print(f"  req {r.rid}: prompt={r.prompt[:6]}... -> "
              f"output={r.output[:8]}...")


if __name__ == "__main__":
    main()
