"""Launchers: mesh construction, dry-run, training and serving drivers.

Deliberately import-light: ``dryrun.py`` must set XLA_FLAGS before any jax
backend initialisation, so this package does not import submodules eagerly.
"""
