"""Distributed tuning plane: sharded multi-worker search + fleet merge.

CLTune-scale spaces (the paper's GEMM case study exceeds 200k
configurations) outgrow one evaluation process, and a fleet of serving
replicas should not each re-tune the same shapes alone.  This package
splits one search across N workers and folds the results back into the
single shared :class:`~repro.core.cache.TuningCache`:

* :func:`shard_space` / :class:`Shard` — strided exact partitioning for
  exhaustive search, or an islands model (per-worker strategy + seed);
* :class:`TuningWorker` / :func:`run_workers` — one shard through the
  standard ``Tuner`` → ``EvaluationEngine`` stack, thread or process
  driver, failures contained per PR 3 semantics;
* :class:`DistributedTuner` — the coordinator: shard, fan out, merge
  private caches (best-finite-time-per-key), publish via merge-on-disk
  save so concurrent fleets converge on one ``tuned_configs.json``.
"""

from .coordinator import (DistributedOutcome, DistributedTuner, ENV_DRIVER,
                          ENV_MODE, ENV_WORKERS)
from .partition import ISLAND_STRATEGIES, Shard, shard_space
from .worker import TuningWorker, WorkerResult, WorkerSpec, run_workers

__all__ = [
    "DistributedOutcome", "DistributedTuner",
    "ENV_DRIVER", "ENV_MODE", "ENV_WORKERS",
    "ISLAND_STRATEGIES", "Shard", "shard_space",
    "TuningWorker", "WorkerResult", "WorkerSpec", "run_workers",
]
