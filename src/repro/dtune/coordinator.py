"""Distributed tuning coordinator: shard, fan out, merge, publish.

``DistributedTuner`` is the driver loop of the distributed plane:

1. **shard** the kernel's search space across N workers
   (:func:`~repro.dtune.partition.shard_space`);
2. **fan out** one :class:`~repro.dtune.worker.TuningWorker` per shard
   (thread or process driver), each recording into a private cache file;
3. **merge** every private cache into the shared one with
   :meth:`TuningCache.merge` — best finite time per key wins, counts
   fold — then :meth:`TuningCache.save` (merge-on-disk) publishes the
   fleet winner;
4. the cache's ``subscribe`` hooks fire for merged-in winners, so live
   :class:`~repro.serve.online.ConfigSlot` holders hot-swap without any
   coordinator → serve plumbing.

Env knobs (all overridable per-call, parsed by
:mod:`repro.core.envknobs`):

* ``REPRO_DTUNE_WORKERS`` — fleet size (default 4)
* ``REPRO_DTUNE_MODE`` — ``strided`` | ``islands`` (default ``strided``)
* ``REPRO_DTUNE_DRIVER`` — ``thread`` | ``process`` (default ``thread``)
* ``REPRO_ARTIFACT_CACHE`` / ``REPRO_ARTIFACT_DIR`` — enable/locate the
  shared compile-artifact store every worker opens (at-most-once
  compiles fleet-wide); an explicit ``artifact_store`` argument wins
"""

from __future__ import annotations

import dataclasses
import logging
import math
import multiprocessing as mp
import os
import shutil
import tempfile
import threading
from typing import Any, Dict, List, Mapping, Optional

from ..core.artifacts import ArtifactStore, resolve_store
from ..core.cache import CacheEntry, TuningCache, default_cache
from ..core.engine import EngineConfig
from ..core.envknobs import env_int, env_str
from ..core.profiles import DeviceProfile, TPU_V5E
from ..core.registry import Shape, resolve
from .partition import Shard, shard_space
from .worker import EvaluatorSpec, WorkerResult, WorkerSpec, run_workers

log = logging.getLogger("repro.dtune")

ENV_WORKERS = "REPRO_DTUNE_WORKERS"
ENV_MODE = "REPRO_DTUNE_MODE"
ENV_DRIVER = "REPRO_DTUNE_DRIVER"

_DEFAULT_WORKERS = 4


@dataclasses.dataclass
class DistributedOutcome:
    """The fleet-level result of one distributed tune."""

    kernel: str
    shape: Dict[str, Any]
    profile: str
    mode: str
    driver: str
    n_workers: int
    best_config: Optional[Dict[str, Any]]
    best_time: float
    best_worker: Optional[int]              # index of the winning worker
    workers: List[WorkerResult]
    evaluations: int                        # fleet total
    #: cache keys the final merge changed (winners other workers lacked)
    merged_keys: List[str]

    @property
    def ok(self) -> bool:
        return self.best_config is not None

    @property
    def per_worker_evaluations(self) -> float:
        """Mean evaluations per worker — the speedup denominator."""
        live = [w for w in self.workers if w.status != "failed"]
        return (sum(w.evaluations for w in live) / len(live)) if live else 0.0

    def report(self) -> str:
        lines = [f"== distributed tune: {self.kernel} {self.shape} "
                 f"profile={self.profile} mode={self.mode} "
                 f"driver={self.driver} workers={self.n_workers} =="]
        for w in self.workers:
            desc = w.status
            if w.best_config is not None:
                desc += (f"  {w.best_time * 1e6:9.2f} us after "
                         f"{w.evaluations} evals  {w.best_config}")
            if w.failures:
                desc += f"  [{w.failures} failed trial(s)]"
            if w.error:
                desc += f"  [{w.error.splitlines()[0]}]"
            lines.append(f"  worker {w.index} ({w.shard_label}): {desc}")
        if self.best_config is None:
            lines.append("  fleet: no feasible config found")
        else:
            lines.append(f"  fleet best: {self.best_time * 1e6:.2f} us "
                         f"(worker {self.best_worker}), "
                         f"{self.evaluations} total evaluations, "
                         f"{self.per_worker_evaluations:.1f}/worker")
        return "\n".join(lines)


class DistributedTuner:
    """Shard one kernel's search across N workers and merge the results.

    The facade mirrors :func:`repro.tune.api.tune_kernel` — same kernel /
    shape / profile / evaluator / cache vocabulary — with fleet knobs on
    top.  ``budget`` is **per worker** (None = exhaustive for strided
    shards, the tuner's 1/32 clamp per island otherwise).  Construction
    is cheap; :meth:`run` does the work and may be called once per
    instance.
    """

    def __init__(self, kernel: "str | Any", shape: Shape, *,
                 n_workers: Optional[int] = None,
                 mode: Optional[str] = None,
                 driver: Optional[str] = None,
                 profile: DeviceProfile = TPU_V5E,
                 evaluator: EvaluatorSpec = None,
                 cache: Optional[TuningCache] = None,
                 artifact_store: "ArtifactStore | str | None" = None,
                 budget: Optional[int] = None,
                 engine: "EngineConfig | Mapping[str, Any] | None" = None,
                 interpret: bool = True,
                 extended_space: Optional[bool] = None,
                 warm_start: "bool | int" = True,
                 seed: int = 0,
                 record: bool = True,
                 objective: "str | Any | None" = None,
                 predictor: "str | Mapping[str, Any] | None" = None):
        self.kernel = resolve(kernel)
        self.shape = dict(shape)
        self.n_workers = (n_workers if n_workers is not None
                          else env_int(ENV_WORKERS, _DEFAULT_WORKERS))
        self.mode = mode or env_str(ENV_MODE, "strided")
        self.driver = driver or env_str(ENV_DRIVER, "thread")
        self.profile = profile
        self.evaluator = evaluator
        self.cache = cache if cache is not None else default_cache()
        # workers only get the store's *directory* (a live store does not
        # pickle); each opens its own ArtifactStore on it and the per-
        # artifact file locks give at-most-once compiles across the fleet
        store = resolve_store(artifact_store)
        self.artifact_dir = store.root if store is not None else None
        self.budget = budget
        if isinstance(engine, EngineConfig):
            engine = {f.name: getattr(engine, f.name)
                      for f in dataclasses.fields(EngineConfig)}
        self.engine: Dict[str, Any] = dict(engine or {})
        if self.engine.get("stop_event") is not None:
            raise ValueError("pass no stop_event; the coordinator owns "
                             "cancellation (use DistributedTuner.stop())")
        self.engine.pop("stop_event", None)
        if objective is not None:
            self.engine["objective"] = objective
        # the objective travels to (possibly spawned) workers inside the
        # engine kwargs dict — canonicalize to its spec string so the dict
        # stays plain picklable data
        if self.engine.get("objective") is not None:
            self.engine["objective"] = str(self.engine["objective"])
        self.objective: Optional[str] = self.engine.get("objective")
        # same discipline as stop_event: a live Predictor does not pickle
        # and must not ride the engine kwargs — the coordinator owns the
        # fleet predictor (trained once, shipped as plain data)
        if self.engine.get("predictor") is not None:
            raise ValueError("pass no live predictor in engine=; use "
                             "DistributedTuner(predictor=...) instead")
        self.engine.pop("predictor", None)
        self.predictor = predictor
        self.interpret = interpret
        if extended_space is None:
            extended_space = bool(
                self.kernel.defaults.get("extended_space", False))
        self.extended_space = bool(extended_space)
        self.warm_start = warm_start
        self.seed = seed
        self.record = record
        self._stop: Optional[Any] = None

    # -- cancellation ---------------------------------------------------------
    def stop(self) -> None:
        """Ask every worker to stop after its current batch (cooperative:
        workers return partial results with ``status='aborted'``)."""
        if self._stop is not None:
            self._stop.set()

    # -- warm start -----------------------------------------------------------
    def _seeds(self) -> Optional[List[Dict[str, Any]]]:
        if self.mode == "strided" or not self.warm_start:
            return None          # full search ignores seeds anyway
        k_nearest = 3 if self.warm_start is True else int(self.warm_start)
        if k_nearest <= 0:
            return None
        # lazy import: tune.api sits above core and below us; importing it
        # lazily keeps dtune importable from either side (same pattern as
        # serve/online.py)
        from ..tune.api import warm_start_seeds
        return warm_start_seeds(self.kernel, self.shape,
                                profile=self.profile, cache=self.cache,
                                k_nearest=k_nearest,
                                objective=self.objective) or None

    # -- fleet predictor ------------------------------------------------------
    def _predictor_spec(self) -> "str | Dict[str, Any] | None":
        """The fleet predictor as plain picklable data.

        Kind ``"learned"`` is resolved *here*: one model trains from the
        coordinator's merged cache (the whole fleet's history) and its
        weights ship to every worker as a ``{"kind", "payload"}`` dict —
        workers reconstruct it without retraining, so all shards rank
        with the same surrogate.  Other kinds travel as strings and are
        instantiated worker-side (they carry no state).
        """
        p = self.predictor
        if p is None:
            return None
        if isinstance(p, Mapping):
            return dict(p)
        if p == "learned":
            from ..core.predict import train_from_cache
            model = train_from_cache(self.kernel, self.cache,
                                     profile=self.profile,
                                     objective=self.objective,
                                     extended=self.extended_space)
            return {"kind": "learned", "payload": model.to_payload()}
        return str(p)

    # -- execution ------------------------------------------------------------
    def run(self, timeout_s: Optional[float] = None) -> DistributedOutcome:
        k = self.kernel
        space = k.make_space(self.shape, extended=self.extended_space)
        shards = shard_space(space, self.n_workers, self.mode,
                             budget=self.budget, seed=self.seed)
        seeds = self._seeds()
        pspec = self._predictor_spec()
        self._stop = (mp.get_context().Event() if self.driver == "process"
                      else threading.Event())
        workdir = tempfile.mkdtemp(prefix="repro-dtune-")
        # everything between mkdtemp and the finally lives inside the try:
        # a crash anywhere here (spec construction, a driver raising, a
        # terminated worker fleet) used to leak the private-cache tempdir
        try:
            specs = [WorkerSpec(
                kernel=k.name, shape=dict(self.shape), shard=shard,
                profile=self.profile.name, evaluator=self.evaluator,
                engine=dict(self.engine), interpret=self.interpret,
                extended_space=self.extended_space,
                cache_path=os.path.join(workdir, f"worker{shard.index}.json"),
                seeds=seeds,
                artifact_dir=self.artifact_dir,
                predictor=pspec) for shard in shards]
            results = run_workers(specs, self.driver,
                                  stop_event=self._stop,
                                  timeout_s=timeout_s)
            merged = self._merge(results) if self.record else {}
        finally:
            shutil.rmtree(workdir, ignore_errors=True)
        best_worker = None
        for w in results:
            if w.ok and math.isfinite(w.best_time) and (
                    best_worker is None
                    or w.best_time < results[best_worker].best_time):
                best_worker = w.index
        best = results[best_worker] if best_worker is not None else None
        for w in results:
            if w.status == "failed":
                log.warning("dtune: worker %s failed: %s", w.shard_label,
                            (w.error or "").splitlines()[0]
                            if w.error else "?")
        return DistributedOutcome(
            kernel=k.name, shape=dict(self.shape), profile=self.profile.name,
            mode=self.mode, driver=self.driver, n_workers=self.n_workers,
            best_config=dict(best.best_config) if best else None,
            best_time=best.best_time if best else math.inf,
            best_worker=best_worker, workers=results,
            evaluations=sum(w.evaluations for w in results),
            merged_keys=sorted(merged))

    def _merge(self, results: List[WorkerResult]) -> Dict[str, CacheEntry]:
        """Fold every worker's private cache into the shared one, then
        publish with a merge-on-disk save.  Returns the changed keys."""
        changed: Dict[str, CacheEntry] = {}
        for w in results:
            if not w.cache_path or not os.path.exists(w.cache_path):
                continue          # failed/empty worker never recorded
            try:
                changed.update(self.cache.merge(w.cache_path))
            except Exception:  # noqa: BLE001 — a torn worker cache must
                # not lose the other workers' results
                log.exception("dtune: could not merge worker cache %s",
                              w.cache_path)
        if changed or len(self.cache):
            self.cache.save()
        return changed
