"""Search-space partitioning for distributed tuning.

Two ways to split one :class:`~repro.core.space.SearchSpace` across N
workers:

* **strided** — worker *i* exhaustively enumerates feasible configs
  ``i, i+n, i+2n, ...`` (``FullSearch(offset=i, stride=n)``).  The shards
  partition the space exactly: every feasible config is evaluated once,
  by exactly one worker, so the merged result equals a single-process
  full search at ~1/n the per-worker cost.  Deterministic, no
  duplicated work, but only meaningful for exhaustive search — a strided
  slice destroys the neighbourhood structure annealing/PSO walk.
* **islands** — every worker sees the *whole* space but runs its own
  strategy (annealing / PSO / evolutionary / random rotation) with its
  own seed, optionally warm-started from nearest-shape cache entries.
  Workers duplicate some evaluations but explore independently; the
  merge keeps whichever island found the best time.  This is the
  classic island model from parallel evolutionary computation, applied
  to CLTune-style kernel search.
"""

from __future__ import annotations

import dataclasses
import logging
from typing import Any, Dict, List, Optional, Sequence

from ..core.space import SearchSpace

log = logging.getLogger("repro.dtune")

#: strategy rotation for islands mode: worker i runs ISLAND_STRATEGIES[i %
#: len].  Ordered so small fleets get the most complementary mix first.
ISLAND_STRATEGIES = ("annealing", "pso", "evolutionary", "random")

#: distinct-seed spacing between islands (any odd constant works; a prime
#: keeps per-worker RNG streams from trivially overlapping)
_SEED_STRIDE = 9973


@dataclasses.dataclass(frozen=True)
class Shard:
    """One worker's slice of a distributed search (picklable, no space)."""

    index: int                              # worker number, 0-based
    total: int                              # fleet size n
    mode: str                               # "strided" | "islands"
    strategy: str                           # strategy name for this worker
    #: strategy constructor kwargs (e.g. offset/stride for strided full)
    strategy_kwargs: Dict[str, Any] = dataclasses.field(default_factory=dict)
    seed: int = 0                           # per-worker RNG seed
    #: per-worker evaluation budget; None = strategy default (exhaustive
    #: for full search, the tuner's 1/32 clamp for stochastic ones)
    budget: Optional[int] = None

    @property
    def label(self) -> str:
        return f"{self.mode}[{self.index}/{self.total}]:{self.strategy}"


def shard_space(space: SearchSpace, n: int, mode: str = "strided", *,
                budget: Optional[int] = None, seed: int = 0,
                strategies: Optional[Sequence[str]] = None) -> List[Shard]:
    """Split ``space`` into ``n`` worker shards.

    ``budget`` is the *per-worker* budget (None = per-strategy default);
    ``seed`` is the base RNG seed, offset per worker so islands explore
    distinct trajectories.  ``strategies`` overrides the islands-mode
    rotation (ignored for strided).  Returns one :class:`Shard` per
    worker; shards carry no reference to the space itself, so they are
    cheap to pickle into worker processes.
    """
    if n < 1:
        raise ValueError(f"need at least one shard; got n={n}")
    if mode not in ("strided", "islands"):
        raise ValueError(f"unknown shard mode {mode!r}; "
                         "known: 'strided', 'islands'")
    if mode == "strided":
        if strategies is not None:
            raise ValueError("strided mode always runs full search; "
                             "use mode='islands' for per-worker strategies")
        card = space.cardinality()
        if n > card:
            # legal — the tail shards simply enumerate nothing — but the
            # caller probably mis-sized the fleet, so say so
            log.warning("shard_space: %d shards over a %d-config space; "
                        "%d worker(s) will be idle", n, card, n - card)
        return [Shard(index=i, total=n, mode=mode, strategy="full",
                      strategy_kwargs={"offset": i, "stride": n},
                      seed=seed, budget=budget)
                for i in range(n)]
    rotation = ISLAND_STRATEGIES if strategies is None else tuple(strategies)
    if not rotation:
        raise ValueError("islands mode needs at least one strategy")
    return [Shard(index=i, total=n, mode=mode,
                  strategy=rotation[i % len(rotation)],
                  seed=seed + i * _SEED_STRIDE, budget=budget)
            for i in range(n)]
