"""Distributed tuning workers: one shard, one EvaluationEngine.

A :class:`TuningWorker` executes one :class:`~repro.dtune.partition.Shard`
of a distributed search by wrapping the exact same stack a single-process
tune uses — ``Tuner.from_tunable`` → ``EvaluationEngine`` — so every PR 3
fault-tolerance behaviour carries over: a failing config becomes a trial,
a circuit-breaker trip yields a *partial* :class:`WorkerResult` with
``status="aborted"`` instead of killing the job, and only an unexpected
crash in the worker scaffolding itself reports ``status="failed"``.

Everything in :class:`WorkerSpec` is plain data (kernel by registered
name, evaluator by name/kwargs spec, profile by name) so a spec crosses a
process boundary by pickling; each worker records into its own *private*
cache file and the coordinator folds those into the shared cache with
:meth:`TuningCache.merge` afterwards.

Two drivers run a fleet of specs:

* ``thread`` — in-process pool.  Zero setup cost; right for analytical /
  cost-model evaluators (pure Python, cheap) and for tests.  Wall-clock
  measurement in concurrent threads contends for the device, so prefer
  processes there.
* ``process`` — one OS process per worker (``fork`` server where
  available, ``spawn`` otherwise).  True isolation: a worker segfaulting
  in a compiler cannot take the coordinator down; results come back over
  a queue and caches over the filesystem.
"""

from __future__ import annotations

import dataclasses
import logging
import multiprocessing as mp
import traceback
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Dict, List, Mapping, Optional, Union

from ..core.cache import TuningCache
from ..core.evaluators import Evaluator, make_evaluator
from ..core.profiles import get_profile
from ..core.registry import resolve
from ..core.tuner import Tuner
from .partition import Shard

log = logging.getLogger("repro.dtune")

#: evaluator specification forms a WorkerSpec accepts: None (the kernel's
#: default), a make_evaluator name, a {"name": ..., **kwargs} dict, or a
#: live Evaluator instance (thread driver / fork only — not spawn-safe)
EvaluatorSpec = Union[None, str, Mapping[str, Any], Evaluator]


def resolve_evaluator(spec: EvaluatorSpec) -> Optional[Evaluator]:
    """Materialize an evaluator from its picklable spec (None passes
    through: ``Tuner.from_tunable`` picks the kernel's default)."""
    if spec is None or isinstance(spec, Evaluator):
        return spec
    if isinstance(spec, str):
        return make_evaluator(spec)
    if isinstance(spec, Mapping):
        kwargs = dict(spec)
        try:
            name = kwargs.pop("name")
        except KeyError:
            raise ValueError("evaluator spec dict needs a 'name' key; "
                             f"got {dict(spec)!r}") from None
        return make_evaluator(name, **kwargs)
    raise TypeError(f"bad evaluator spec: {spec!r}")


@dataclasses.dataclass
class WorkerSpec:
    """Everything one worker needs, as plain picklable data."""

    kernel: str                             # registered TunableKernel name
    shape: Dict[str, Any]
    shard: Shard
    profile: str = "tpu_v5e"                # DeviceProfile by name
    evaluator: EvaluatorSpec = None
    #: EngineConfig kwargs (workers, prune_factor, max_failures, ...);
    #: the runtime stop event is injected separately, never pickled
    engine: Dict[str, Any] = dataclasses.field(default_factory=dict)
    interpret: bool = True
    extended_space: bool = False
    #: private cache file this worker records its shard winner into;
    #: None = don't record (results only travel via WorkerResult)
    cache_path: Optional[str] = None
    #: warm-start seed configs (nearest-shape winners, heuristics)
    seeds: Optional[List[Dict[str, Any]]] = None
    #: root directory of the *shared* compile-artifact store (picklable
    #: path, not a live store): every worker opens its own ArtifactStore
    #: on it, and the store's per-artifact cross-process locks make each
    #: distinct artifact compile at most once fleet-wide.  None = no store.
    artifact_dir: Optional[str] = None
    #: predictor as plain picklable data: None (the REPRO_PREDICTOR env
    #: default), a kind string, or a ``{"kind", "payload"}`` dict carrying
    #: a fleet-trained LearnedPredictor's weights — the coordinator trains
    #: ONE model from the merged cache and ships it to every worker, so
    #: the whole fleet ranks with the same surrogate (never a live object)
    predictor: "str | Dict[str, Any] | None" = None


@dataclasses.dataclass
class WorkerResult:
    """One worker's outcome, as plain data (crosses process boundaries)."""

    index: int
    shard_label: str
    #: "ok" | "aborted" (circuit breaker / stop event, partial result) |
    #: "empty" (no feasible config in the shard) | "failed" (worker crash)
    status: str
    best_config: Optional[Dict[str, Any]] = None
    best_time: float = float("inf")
    evaluations: int = 0
    failures: int = 0                       # failed-config trials
    error: Optional[str] = None             # set when status == "failed"
    cache_path: Optional[str] = None
    engine_stats: Optional[Dict[str, Any]] = None

    @property
    def ok(self) -> bool:
        return self.status in ("ok", "aborted") \
            and self.best_config is not None


class TuningWorker:
    """Run one shard of a distributed search through the standard stack."""

    def __init__(self, spec: WorkerSpec, stop_event: Optional[Any] = None):
        self.spec = spec
        self.stop_event = stop_event

    def run(self) -> WorkerResult:
        spec = self.spec
        shard = spec.shard
        try:
            return self._run()
        except Exception as e:  # noqa: BLE001 — one worker crashing must
            # surface as a failed *result*, not kill the whole fleet
            log.exception("dtune: worker %s crashed", shard.label)
            return WorkerResult(
                index=shard.index, shard_label=shard.label, status="failed",
                error=f"{type(e).__name__}: {e}\n"
                      f"{traceback.format_exc(limit=5)}",
                cache_path=spec.cache_path)

    def _run(self) -> WorkerResult:
        spec = self.spec
        shard = spec.shard
        k = resolve(spec.kernel)
        cache = TuningCache(spec.cache_path) if spec.cache_path else None
        tuner = Tuner.from_tunable(
            k, spec.shape,
            evaluator=resolve_evaluator(spec.evaluator),
            profile=get_profile(spec.profile),
            cache=cache, artifact_store=spec.artifact_dir,
            interpret=spec.interpret,
            extended_space=spec.extended_space)
        engine = dict(spec.engine)
        if self.stop_event is not None:
            engine["stop_event"] = self.stop_event
        outcome = tuner.tune(
            strategy=shard.strategy, budget=shard.budget, seed=shard.seed,
            record_to_cache=spec.cache_path is not None,
            shape_key=k.key_for(spec.shape), engine=engine,
            seeds=spec.seeds or None, predictor=spec.predictor,
            **shard.strategy_kwargs)
        result = outcome.result
        best = result.best
        if result.extra.get("aborted"):
            status = "aborted"
        elif best is None:
            status = "empty"
        else:
            status = "ok"
        return WorkerResult(
            index=shard.index, shard_label=shard.label, status=status,
            best_config=dict(best.config) if best else None,
            best_time=best.time if best else float("inf"),
            evaluations=result.evaluations,
            failures=outcome.failure_summary["failed_trials"],
            cache_path=spec.cache_path,
            engine_stats=result.extra.get("engine"))


# -- drivers -------------------------------------------------------------------

def _process_entry(spec: WorkerSpec, queue: "mp.Queue",
                   stop_event: Optional[Any] = None) -> None:
    """Module-level child entry point (picklable under spawn)."""
    result = TuningWorker(spec, stop_event).run()
    queue.put(dataclasses.asdict(result))


def run_workers(specs: List[WorkerSpec], driver: str = "thread", *,
                stop_event: Optional[Any] = None,
                timeout_s: Optional[float] = None) -> List[WorkerResult]:
    """Execute every spec and return results in spec order.

    ``driver="thread"`` runs workers on an in-process pool sized to the
    fleet; ``driver="process"`` forks/spawns one OS process per worker.
    ``stop_event`` (optional) is handed to every worker's engine for
    cooperative early stop; with the process driver it must be a
    ``multiprocessing.Event``.  A worker that crashes, dies, or exceeds
    ``timeout_s`` yields a ``status="failed"`` result — never an
    exception out of this function.
    """
    if driver == "thread":
        with ThreadPoolExecutor(max_workers=max(1, len(specs)),
                                thread_name_prefix="dtune-worker") as pool:
            futures = [pool.submit(TuningWorker(s, stop_event).run)
                       for s in specs]
            return [f.result() for f in futures]
    if driver != "process":
        raise ValueError(f"unknown dtune driver {driver!r}; "
                         "known: 'thread', 'process'")
    # fork keeps live registry/evaluator state; spawn is the portable
    # fallback and is why WorkerSpec is all-plain-data
    method = "fork" if "fork" in mp.get_all_start_methods() else "spawn"
    ctx = mp.get_context(method)
    queues = [ctx.Queue() for _ in specs]
    procs = []
    for spec, q in zip(specs, queues):
        # NB a stop_event crossing this boundary must be a
        # multiprocessing.Event from a compatible context; a plain
        # threading.Event would fail to pickle under spawn
        p = ctx.Process(target=_process_entry, args=(spec, q, stop_event),
                        name=f"dtune-{spec.shard.label}")
        p.start()
        procs.append(p)
    results: List[WorkerResult] = []
    for spec, p, q in zip(specs, procs, queues):
        shard = spec.shard
        try:
            results.append(WorkerResult(**q.get(timeout=timeout_s)))
        except Exception as e:  # noqa: BLE001 — queue.Empty on timeout,
            # or a child that died before putting anything
            results.append(WorkerResult(
                index=shard.index, shard_label=shard.label, status="failed",
                error=f"worker process yielded no result ({e!r})",
                cache_path=spec.cache_path))
        p.join(timeout=5.0)
        if p.is_alive():
            p.terminate()
            p.join(timeout=5.0)
    return results
